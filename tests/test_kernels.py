"""Per-kernel validation: Pallas (interpret mode) vs the ref.py jnp oracles.

Sweeps shapes/dtypes with hypothesis per the assignment; every kernel must
match its oracle to fp32 tolerance, including ragged (non-multiple) shapes
and stacked leading axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import correlation
from repro.kernels import ref
from repro.kernels.coap_update import (
    coap_fused_update_bp_pallas,
    coap_fused_update_pallas,
)
from repro.kernels.eqn6 import eqn6_sgd_update_pallas
from repro.kernels.quant8 import (
    coap_fused_update_q8_pallas,
    dequantize_blockwise_pallas,
    quantize_blockwise_pallas,
    quantized_adam_update_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(seed), shape).astype(dtype)


# ---------------------------------------------------------------------------
# coap_update kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 520),
    n=st.integers(128, 700),
    r=st.sampled_from([16, 64, 128]),
    count=st.integers(1, 1000),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_coap_fused_update_matches_ref(m, n, r, count, dtype):
    g = _rand((m, n), 0, dtype)
    p = _rand((n, r), 1) / np.sqrt(r)
    mm = 0.1 * _rand((m, r), 2)
    vv = jnp.abs(0.01 * _rand((m, r), 3))
    cnt = jnp.asarray(count, jnp.int32)
    got = coap_fused_update_pallas(g, p, mm, vv, cnt, interpret=True, bm=128, bn=256)
    want = ref.coap_fused_update(g, p, mm, vv, cnt)
    for a, b, name in zip(got, want, ["m", "v", "delta"]):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5, err_msg=name)


def test_coap_fused_update_stacked_axes():
    g = _rand((2, 3, 130, 260), 0)
    p = _rand((2, 3, 260, 32), 1) / np.sqrt(32)
    mm = jnp.zeros((2, 3, 130, 32))
    vv = jnp.zeros((2, 3, 130, 32))
    cnt = jnp.asarray(7, jnp.int32)
    got = coap_fused_update_pallas(g, p, mm, vv, cnt, interpret=True, bm=64, bn=128)
    want = ref.coap_fused_update(g, p, mm, vv, cnt)
    np.testing.assert_allclose(got[2], want[2], rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# coap_update back-projection-fused kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 520),
    n=st.integers(128, 700),
    r=st.sampled_from([16, 64, 128]),
    count=st.integers(1, 1000),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_coap_fused_update_bp_matches_ref(m, n, r, count, dtype):
    g = _rand((m, n), 0, dtype)
    p = _rand((n, r), 1) / np.sqrt(r)
    mm = 0.1 * _rand((m, r), 2)
    vv = jnp.abs(0.01 * _rand((m, r), 3))
    cnt = jnp.asarray(count, jnp.int32)
    got = coap_fused_update_bp_pallas(
        g, p, mm, vv, cnt, interpret=True, bm=128, bn=256
    )
    want = ref.coap_fused_update_bp(g, p, mm, vv, cnt)
    for a, b, name in zip(got, want, ["m", "v", "dw"]):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5, err_msg=name)


def test_coap_fused_update_bp_stacked_axes():
    g = _rand((2, 3, 130, 260), 0)
    p = _rand((2, 3, 260, 32), 1) / np.sqrt(32)
    mm = jnp.zeros((2, 3, 130, 32))
    vv = jnp.zeros((2, 3, 130, 32))
    cnt = jnp.asarray(7, jnp.int32)
    got = coap_fused_update_bp_pallas(g, p, mm, vv, cnt, interpret=True,
                                      bm=64, bn=128)
    want = ref.coap_fused_update_bp(g, p, mm, vv, cnt)
    np.testing.assert_allclose(got[2], want[2], rtol=3e-5, atol=3e-5)


def test_coap_fused_update_bp_consistent_with_nonbp():
    """ΔW from the fused kernel == Δ_proj Pᵀ of the non-BP kernel."""
    m, n, r = 300, 520, 48
    g = _rand((m, n), 0)
    p = _rand((n, r), 1) / np.sqrt(r)
    mm = 0.1 * _rand((m, r), 2)
    vv = jnp.abs(0.01 * _rand((m, r), 3))
    cnt = jnp.asarray(5, jnp.int32)
    nm1, nv1, delta = coap_fused_update_pallas(
        g, p, mm, vv, cnt, interpret=True, bm=128, bn=256
    )
    nm2, nv2, dw = coap_fused_update_bp_pallas(
        g, p, mm, vv, cnt, interpret=True, bm=128, bn=256
    )
    np.testing.assert_array_equal(np.asarray(nm1), np.asarray(nm2))
    np.testing.assert_array_equal(np.asarray(nv1), np.asarray(nv2))
    np.testing.assert_allclose(dw, delta @ p.T, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# quant8 kernels
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    numel=st.integers(1, 5000),
    scale_pow=st.integers(-6, 3),
    seed=st.integers(0, 100),
)
def test_quantize_roundtrip_matches_ref(numel, scale_pow, seed):
    x = (10.0**scale_pow) * _rand((numel,), seed)
    q_k, s_k = quantize_blockwise_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_blockwise(x)
    np.testing.assert_array_equal(q_k, q_r)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-6)
    x_k = dequantize_blockwise_pallas(q_k, s_k, (numel,), interpret=True)
    x_r = ref.dequantize_blockwise(q_r, s_r, (numel,))
    np.testing.assert_allclose(x_k, x_r, rtol=1e-6)
    # quantization error bound: |x - dq| <= scale/2 per block element
    err = np.abs(np.asarray(x) - np.asarray(x_k))
    per_block_bound = np.repeat(np.asarray(s_r), ref.QUANT_BLOCK)[:numel] * 0.5 + 1e-12
    assert (err <= per_block_bound + 1e-9).all()


def test_quantize_zero_block_safe():
    x = jnp.zeros((512,))
    q, s = quantize_blockwise_pallas(x, interpret=True)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 0))
    back = dequantize_blockwise_pallas(q, s, (512,), interpret=True)
    assert bool(jnp.all(back == 0))


@settings(max_examples=5, deadline=None)
@given(m=st.integers(8, 200), r=st.sampled_from([16, 64]), seed=st.integers(0, 50))
def test_quantized_adam_update_matches_ref(m, r, seed):
    g = 0.1 * _rand((m, r), seed)
    m0 = 0.05 * _rand((m, r), seed + 1)
    v0 = jnp.abs(0.01 * _rand((m, r), seed + 2))
    mq, ms = ref.quantize_blockwise(m0)
    vq, vs = ref.quantize_blockwise(v0)
    cnt = jnp.asarray(3, jnp.int32)
    got = quantized_adam_update_pallas(g, mq, ms, vq, vs, cnt, interpret=True)
    want = ref.quantized_adam_update(g, mq, ms, vq, vs, cnt)
    for a, b, name in zip(got, want, ["mq", "ms", "vq", "vs", "delta"]):
        if a.dtype == jnp.int8:
            # rounding at the exact .5 boundary may differ by 1 code
            assert int(jnp.max(jnp.abs(a.astype(jnp.int32) - b.astype(jnp.int32)))) <= 1
        else:
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# row-block codec + single-pass fused 8-bit COAP kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(1, 130),
    r=st.sampled_from([8, 100, 256, 300, 512]),
    scale_pow=st.integers(-6, 3),
    seed=st.integers(0, 100),
)
def test_rowblock_roundtrip(m, r, scale_pow, seed):
    """Codec invariants incl. ragged r (tail block shorter than 256)."""
    x = (10.0**scale_pow) * _rand((m, r), seed)
    q, s = ref.quantize_rowblock(x)
    assert q.shape == (m, r) and q.dtype == jnp.int8
    assert s.shape == (m, ref.rowblock_nblocks(r))
    back = ref.dequantize_rowblock(q, s)
    # absmax codec: error <= scale/2 per element, scales per row-block
    err = np.abs(np.asarray(x) - np.asarray(back))
    bound = np.repeat(np.asarray(s), ref.QUANT_BLOCK, axis=-1)[:, :r]
    assert (err <= 0.5 * bound + 1e-12).all()


def test_rowblock_matches_flat_codec_when_aligned():
    """For r a multiple of 256 the two codecs must emit identical codes."""
    x = _rand((64, 512), 0)
    q_row, s_row = ref.quantize_rowblock(x)
    q_flat, s_flat = ref.quantize_blockwise(x)
    np.testing.assert_array_equal(
        np.asarray(q_row).reshape(-1, ref.QUANT_BLOCK), np.asarray(q_flat)
    )
    np.testing.assert_array_equal(np.asarray(s_row).reshape(-1),
                                  np.asarray(s_flat))


@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 300),
    n=st.sampled_from([128, 256, 520]),
    r=st.sampled_from([32, 48, 300]),
    count=st.integers(1, 500),
)
def test_coap_fused_update_q8_exact_codes(m, n, r, count):
    """With a single n-block the kernel's G@P is the oracle's dot — the
    requantized int8 states must be BIT-EXACT, scales/ΔW to fp32 ulp."""
    g = 0.1 * _rand((m, n), 0)
    p = _rand((n, r), 1) / np.sqrt(r)
    m0 = 0.05 * _rand((m, r), 2)
    v0 = jnp.abs(0.01 * _rand((m, r), 3))
    mq, ms = ref.quantize_rowblock(m0)
    vq, vs = ref.quantize_rowblock(v0)
    cnt = jnp.asarray(count, jnp.int32)
    got = coap_fused_update_q8_pallas(
        g, p, mq, ms, vq, vs, cnt, interpret=True, bm=64, bn=1024
    )
    want = ref.coap_fused_update_q8(g, p, mq, ms, vq, vs, cnt)
    for a, b, name in zip(got[:4:2], want[:4:2], ["mq", "vq"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    for i, name in [(1, "ms"), (3, "vs"), (4, "dw")]:
        np.testing.assert_allclose(got[i], want[i], rtol=1e-5, atol=1e-5,
                                   err_msg=name)


def test_coap_fused_update_q8_ragged_multiblock():
    """Ragged m/n with n split across blocks: accumulation order differs
    from the oracle, so codes may differ by the .5-rounding code at most."""
    m, n, r = 300, 700, 48
    g = 0.1 * _rand((m, n), 0)
    p = _rand((n, r), 1) / np.sqrt(r)
    m0 = 0.05 * _rand((m, r), 2)
    v0 = jnp.abs(0.01 * _rand((m, r), 3))
    mq, ms = ref.quantize_rowblock(m0)
    vq, vs = ref.quantize_rowblock(v0)
    cnt = jnp.asarray(9, jnp.int32)
    got = coap_fused_update_q8_pallas(
        g, p, mq, ms, vq, vs, cnt, interpret=True, bm=128, bn=256
    )
    want = ref.coap_fused_update_q8(g, p, mq, ms, vq, vs, cnt)
    for a, b, name in zip(got, want, ["mq", "ms", "vq", "vs", "dw"]):
        if a.dtype == jnp.int8:
            diff = np.abs(np.asarray(a, np.int32) - np.asarray(b, np.int32))
            assert diff.max() <= 1, name
        else:
            np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5,
                                       err_msg=name)


def test_coap_fused_update_q8_stacked_leaves():
    """Stacked (L, m, n) leaves — the shape the bucketed optimizer emits."""
    g = 0.1 * _rand((4, 130, 260), 0)
    p = _rand((4, 260, 32), 1) / np.sqrt(32)
    m0 = 0.05 * _rand((4, 130, 32), 2)
    v0 = jnp.abs(0.01 * _rand((4, 130, 32), 3))
    mq, ms = ref.quantize_rowblock(m0)
    vq, vs = ref.quantize_rowblock(v0)
    cnt = jnp.asarray(7, jnp.int32)
    got = coap_fused_update_q8_pallas(
        g, p, mq, ms, vq, vs, cnt, interpret=True, bm=64, bn=512
    )
    want = ref.coap_fused_update_q8(g, p, mq, ms, vq, vs, cnt)
    for a, b, name in zip(got, want, ["mq", "ms", "vq", "vs", "dw"]):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=3e-5, atol=3e-5, err_msg=name,
        )


def test_coap_fused_update_q8_underflow_clip_guard():
    """The int8-v underflow guard: when V quantizes to all-zero codes while
    M does not, the raw bias-corrected Δ is ~1/eps; the kernel must emit the
    clipped value (and match the oracle bit-for-bit on codes)."""
    m, n, r = 32, 128, 16
    g = jnp.zeros((m, n))  # no gradient: moments keep their stored values
    p = _rand((n, r), 1) / np.sqrt(r)
    m0 = 1e-3 * jnp.ones((m, r))
    mq, ms = ref.quantize_rowblock(m0)
    vq = jnp.zeros((m, r), jnp.int8)  # V underflowed to zero codes
    vs = jnp.zeros((m, ref.rowblock_nblocks(r)))
    cnt = jnp.asarray(100, jnp.int32)
    got = coap_fused_update_q8_pallas(
        g, p, mq, ms, vq, vs, cnt, interpret=True, bm=32, bn=256
    )
    want = ref.coap_fused_update_q8(g, p, mq, ms, vq, vs, cnt)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(got[4], want[4], rtol=1e-5, atol=1e-6)
    # the guard really engaged: unclipped Δ would be ~m/eps >> clip
    raw = float(
        (0.9 * 1e-3 / (1 - 0.9**100)) / (0.0 + 1e-8)
    )
    assert raw > ref.QUANT_DELTA_CLIP * 100
    # and ΔW stays bounded by clip * ||P||_1 per row
    assert np.isfinite(np.asarray(got[4])).all()


# ---------------------------------------------------------------------------
# eqn6 fused refresh kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 520),
    n=st.integers(24, 700),
    r=st.sampled_from([8, 32, 100]),
    seed=st.integers(0, 100),
)
def test_eqn6_kernel_matches_loss_and_grad_oracle(m, n, r, seed):
    """steps=1: the kernel's val/grad must pin against the closed-form
    ``correlation.loss_and_grad`` oracle and its P update against
    ``correlation.sgd_update`` (ragged shapes included)."""
    r = min(r, n)
    g = _rand((m, n), seed)
    p = _rand((n, r), seed + 1) / np.sqrt(r)
    mp = 0.1 * _rand((m, r), seed + 2)
    p_new, val, grad = eqn6_sgd_update_pallas(
        g=g, p=p, m_proj=mp, lr=0.1, steps=1, interpret=True, bm=64
    )
    want_val, want_grad = correlation.loss_and_grad(p, g, mp)
    np.testing.assert_allclose(val, want_val, rtol=1e-4)
    np.testing.assert_allclose(grad, want_grad, rtol=1e-3, atol=1e-6)
    want_p = correlation.sgd_update(p, g, mp, lr=0.1, steps=1)
    np.testing.assert_allclose(p_new, want_p, rtol=1e-4, atol=1e-6)


def test_eqn6_kernel_multistep_matches_sgd_update():
    """Multi-step SGD loops the grid: G is re-streamed per step against the
    in-VMEM-updated P; must track the oracle's fori_loop."""
    m, n, r = 300, 260, 32
    g = _rand((m, n), 0)
    p = _rand((n, r), 1) / np.sqrt(r)
    mp = 0.1 * _rand((m, r), 2)
    for steps in (2, 5):
        got, _, _ = eqn6_sgd_update_pallas(
            p, g, mp, lr=0.05, steps=steps, interpret=True, bm=128
        )
        want = correlation.sgd_update(p, g, mp, lr=0.05, steps=steps)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_eqn6_kernel_bf16_gradient():
    """bf16 G/M stream straight into the kernel (per-tile VMEM upcast); the
    result must match the oracle fed the same bf16 inputs (upcasting is
    value-exact, so tolerance stays fp32-tight)."""
    m, n, r = 130, 260, 32
    g = _rand((m, n), 0, jnp.bfloat16)
    p = _rand((n, r), 1) / np.sqrt(r)
    mp = (0.1 * _rand((m, r), 2)).astype(jnp.bfloat16)
    p_new, val, grad = eqn6_sgd_update_pallas(
        p, g, mp, lr=0.1, steps=1, interpret=True, bm=64
    )
    want_val, want_grad = correlation.loss_and_grad(
        p, g.astype(jnp.float32), mp.astype(jnp.float32)
    )
    np.testing.assert_allclose(val, want_val, rtol=1e-4)
    np.testing.assert_allclose(grad, want_grad, rtol=1e-3, atol=1e-6)
    want_p = correlation.sgd_update(p, g, mp, lr=0.1, steps=1)
    np.testing.assert_allclose(p_new, want_p, rtol=1e-4, atol=1e-6)


def test_eqn6_kernel_stacked_axes():
    """Stacked (L, ...) leaves — the shape the bucketed refresh emits."""
    g = _rand((2, 3, 130, 260), 0)
    p = _rand((2, 3, 260, 32), 1) / np.sqrt(32)
    mp = 0.1 * _rand((2, 3, 130, 32), 2)
    p_new, val, grad = eqn6_sgd_update_pallas(
        p, g, mp, lr=0.1, steps=1, interpret=True, bm=64
    )
    want_val, want_grad = correlation.loss_and_grad(p, g, mp)
    assert val.shape == (2, 3)
    np.testing.assert_allclose(val, want_val, rtol=1e-4)
    np.testing.assert_allclose(grad, want_grad, rtol=1e-3, atol=1e-6)


def test_eqn6_ref_oracle_is_sgd_update():
    """ref.eqn6_sgd_update must be bit-identical to correlation.sgd_update
    (it IS the same fori_loop, re-exposed in the kernel signature) — for
    the plain AND the normalize variant."""
    g = _rand((64, 48), 7)
    p = _rand((48, 8), 8) / np.sqrt(8)
    mp = 0.1 * _rand((64, 8), 9)
    for normalize in (False, True):
        got, _val, _grad = ref.eqn6_sgd_update(
            p, g, mp, lr=0.1, steps=3, normalize=normalize
        )
        want = correlation.sgd_update(
            p, g, mp, lr=0.1, steps=3, normalize=normalize
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 400),
    n=st.integers(24, 500),
    r=st.sampled_from([8, 32, 100]),
    steps=st.sampled_from([1, 3]),
    seed=st.integers(0, 100),
)
def test_eqn6_kernel_normalize_matches_oracle(m, n, r, steps, seed):
    """normalize=True is fused via a first-grid-phase ‖G‖ pre-pass; the
    result must track the jnp oracle's pre-scaled SGD (including tiny
    gradients, where normalization is the whole point)."""
    r = min(r, n)
    g = 1e-3 * _rand((m, n), seed)  # small G: inert without normalization
    p = _rand((n, r), seed + 1) / np.sqrt(r)
    mp = 1e-4 * _rand((m, r), seed + 2)
    p_new, _val, _grad = eqn6_sgd_update_pallas(
        p, g, mp, lr=0.1, steps=steps, interpret=True, bm=64, normalize=True
    )
    want = correlation.sgd_update(p, g, mp, lr=0.1, steps=steps,
                                  normalize=True)
    np.testing.assert_allclose(p_new, want, rtol=1e-4, atol=1e-6)
    # normalization engaged: the un-normalized refresh would barely move P
    frozen = correlation.sgd_update(p, g, mp, lr=0.1, steps=steps)
    assert float(jnp.max(jnp.abs(p_new - p))) > 10 * float(
        jnp.max(jnp.abs(frozen - p))
    )


def test_eqn6_kernel_normalize_bf16_and_stacked():
    g = _rand((2, 130, 260), 0, jnp.bfloat16)
    p = _rand((2, 260, 32), 1) / np.sqrt(32)
    mp = (0.1 * _rand((2, 130, 32), 2)).astype(jnp.bfloat16)
    p_new, _v, _g = eqn6_sgd_update_pallas(
        p, g, mp, lr=0.1, steps=2, interpret=True, bm=64, normalize=True
    )
    want = correlation.sgd_update(p, g, mp, lr=0.1, steps=2, normalize=True)
    np.testing.assert_allclose(p_new, want, rtol=1e-4, atol=1e-6)


def test_sgd_update_normalize_routes_fused(monkeypatch):
    """use_fused + normalize must dispatch the fused kernel — the unfused
    fallback for normalize is gone (ROADMAP item closed)."""
    from repro.kernels import ops as kops

    calls = []
    orig = kops.eqn6_sgd_update

    def counting(*a, **k):
        calls.append(k.get("normalize"))
        return orig(*a, **k)

    monkeypatch.setattr(kops, "eqn6_sgd_update", counting)
    g = _rand((64, 48), 0)
    p = _rand((48, 8), 1) / np.sqrt(8)
    mp = 0.1 * _rand((64, 8), 2)
    correlation.sgd_update(p, g, mp, use_fused=True, normalize=True)
    assert calls == [True]


# ---------------------------------------------------------------------------
# eqn6 VMEM guard
# ---------------------------------------------------------------------------
def test_eqn6_plan_bm_shrinks_and_falls_back():
    from repro.kernels.eqn6 import Eqn6VmemError, eqn6_vmem_bytes, plan_bm

    # comfortable shapes keep the requested tile
    assert plan_bm(4096, 256, 64) == 256
    # tight budget: bm halves until the tile traffic fits
    assert plan_bm(4096, 512, 128, bm=256, budget=2_500_000) == 128
    # the resident (n, r) buffers are bm-independent: when they alone bust
    # the budget no bm helps -> None (LLaMA-1B wide case at 16MB/core)
    assert plan_bm(4096, 2048, 512, budget=16 * 1024 * 1024) is None
    # estimate is monotone in bm and accounts bf16 tiles as smaller
    assert eqn6_vmem_bytes(64, 512, 128) < eqn6_vmem_bytes(256, 512, 128)
    assert eqn6_vmem_bytes(
        64, 512, 128, g_itemsize=2, mp_itemsize=2
    ) < eqn6_vmem_bytes(64, 512, 128)
    # the kernel wrapper raises the typed error instead of compiling an
    # unfittable kernel
    g = _rand((64, 256), 0)
    p = _rand((256, 64), 1) / 8.0
    mp = 0.1 * _rand((64, 64), 2)
    with pytest.raises(Eqn6VmemError):
        eqn6_sgd_update_pallas(p, g, mp, interpret=True, vmem_budget=1024)


def test_eqn6_ops_falls_back_unfused_on_vmem(monkeypatch):
    """kernels/ops dispatch catches the VMEM error and falls back to the
    jnp oracle (identical numerics) with a warning, instead of dying."""
    import warnings

    from repro.kernels import eqn6 as eqn6_mod
    from repro.kernels import ops as kops

    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    monkeypatch.setenv(eqn6_mod._VMEM_ENV, "1024")  # nothing fits
    kops.reset_eqn6_fallbacks()  # the warning dedupes per (n, r, budget)
    g = _rand((64, 48), 3)
    p = _rand((48, 8), 4) / np.sqrt(8)
    mp = 0.1 * _rand((64, 8), 5)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = kops.eqn6_sgd_update(p, g, mp, lr=0.1, steps=2)
    assert any("VMEM" in str(w.message) or "Eqn-6" in str(w.message)
               for w in caught)
    # ...and the fallback is COUNTED (plan/dryrun telemetry satellite)
    assert kops.eqn6_fallback_counts()[(64, 48, 8)] == 1
    want = correlation.sgd_update(p, g, mp, lr=0.1, steps=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# rmsnorm kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    rows=st.integers(1, 300),
    d=st.sampled_from([128, 256, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 20),
)
def test_rmsnorm_matches_ref(rows, d, dtype, seed):
    x = _rand((rows, d), seed, dtype)
    scale = 1.0 + 0.1 * _rand((d,), seed + 1)
    got = rmsnorm_pallas(x, scale, interpret=True, bm=64)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_rmsnorm_3d_shape():
    x = _rand((4, 7, 256), 0)
    scale = jnp.ones((256,))
    got = rmsnorm_pallas(x, scale, interpret=True, bm=8)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
