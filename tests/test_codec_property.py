"""Property-based stacked-state codec tests (stacked-bucket/v2).

Randomized pytrees mixing dense, projected and conv (Tucker-2) leaves —
drawn through ``hypothesis`` (or the deterministic ``tests/conftest.py``
shim when the real package is absent) — must satisfy, for every draw:

  * ``decode(encode(x)) == x`` bit-for-bit, int8 codes and scales
    included, with ``leaf_view`` agreeing at every flat index;
  * the layout is a partition: every flat leaf index appears exactly once
    across buckets + tail, projected buckets first, conv before dense;
  * every ``manifest_entries`` logical path resolves back to its leaf:
    stacked entries' axis-0 slices equal the per-leaf arrays their
    ``slots`` name, and the stacked and per-leaf walks of the same state
    expose the identical logical-path namespace;
  * the codec tag is ``stacked-bucket/v2`` with v1 still decodable.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import stacked_state as ss
from repro.core.coap_adam import ProjectedAdamConfig, scale_by_projected_adam
from repro.core.projector import ProjectionRules

# Congruence pools: several leaves may share a signature (multi-leaf
# buckets) or not (singletons) depending on the draw.
_PROJ_SHAPES = [(48, 32), (64, 24), (32, 48)]
_CONV_SHAPES = [(16, 12, 3, 3), (16, 16, 3, 3), (12, 16, 2, 2)]
_DENSE_SHAPES = [(7,), (4, 4), (9,)]


def _build_params(n_proj, n_conv, n_dense, seed):
    """Deterministic mixed tree from the draw; >=1 leaf guaranteed."""
    rng = np.random.RandomState(seed)
    p = {}
    for j in range(n_proj):
        shape = _PROJ_SHAPES[rng.randint(len(_PROJ_SHAPES))]
        p[f"proj{j}"] = {"w": jnp.zeros(shape)}
    for j in range(n_conv):
        shape = _CONV_SHAPES[rng.randint(len(_CONV_SHAPES))]
        p[f"conv{j}_kernel"] = 0.01 * jnp.ones(shape)
    for j in range(n_dense + 1):  # always at least one leaf in the tree
        shape = _DENSE_SHAPES[rng.randint(len(_DENSE_SHAPES))]
        p[f"bias{j}"] = jnp.zeros(shape)
    return p


def _stepped_state(params, quantize, seed):
    """An optimizer state with non-trivial contents (one jitted step)."""
    cfg = ProjectedAdamConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
        quantize=quantize,
    )
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    g = jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), x.shape)
            for i, x in enumerate(flat)
        ],
    )
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, state)
    return cfg, state


def _layout_for(cfg, params):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return ss.layout_for_flat(cfg.rules.spec_for, flat)


@settings(max_examples=6, deadline=None)
@given(
    n_proj=st.integers(min_value=0, max_value=4),
    n_conv=st.integers(min_value=0, max_value=4),
    n_dense=st.integers(min_value=0, max_value=2),
    quantize=st.booleans(),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_roundtrip_bitexact_random_trees(n_proj, n_conv, n_dense, quantize,
                                         seed):
    """decode(encode(x)) == x bit-for-bit and leaf_view == decode at every
    index, for randomized mixed trees under stacked-bucket/v2."""
    params = _build_params(n_proj, n_conv, n_dense, seed)
    cfg, state = _stepped_state(params, quantize, seed)
    layout = _layout_for(cfg, params)
    treedef = jax.tree_util.tree_structure(params)
    flat_states = treedef.flatten_up_to(state.leaves)

    stacked = ss.encode(layout, flat_states)
    decoded = ss.decode(stacked)
    assert len(decoded) == len(flat_states) == layout.n_leaves
    for a, b in zip(
        jax.tree_util.tree_leaves(flat_states),
        jax.tree_util.tree_leaves(decoded),
    ):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for i in range(layout.n_leaves):
        for a, b in zip(
            jax.tree_util.tree_leaves(ss.leaf_view(stacked, i)),
            jax.tree_util.tree_leaves(decoded[i]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=6, deadline=None)
@given(
    n_proj=st.integers(min_value=0, max_value=4),
    n_conv=st.integers(min_value=0, max_value=4),
    n_dense=st.integers(min_value=0, max_value=2),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_layout_partitions_every_leaf(n_proj, n_conv, n_dense, seed):
    """The layout is a partition of the flat indices with the v2 bucket
    order (project, conv, dense) and an empty tail under the default
    classification; bucket members share their congruence signature."""
    params = _build_params(n_proj, n_conv, n_dense, seed)
    cfg = ProjectedAdamConfig(rules=ProjectionRules(rank=8, min_dim=8))
    layout = _layout_for(cfg, params)
    assert layout.version == ss.STACKED_STATE_VERSION == 2
    assert layout.tail == ()
    seen = sorted(i for b in layout.buckets for i in b.indices)
    assert seen == list(range(layout.n_leaves))
    order = [b.kind for b in layout.buckets]
    rank = {ss.BUCKET_PROJECT: 0, ss.BUCKET_CONV: 1, ss.BUCKET_DENSE: 2}
    assert order == sorted(order, key=rank.__getitem__)
    for b in layout.buckets:
        assert len(b.indices) == len(b.paths) >= 1
        assert len(b.indices) == len(set(b.indices))
    assert layout.staggerable_bucket_sizes() == (
        layout.proj_bucket_sizes() + layout.conv_bucket_sizes()
    )


@settings(max_examples=6, deadline=None)
@given(
    n_proj=st.integers(min_value=0, max_value=3),
    n_conv=st.integers(min_value=1, max_value=4),
    n_dense=st.integers(min_value=0, max_value=2),
    quantize=st.booleans(),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_manifest_logical_paths_resolve(n_proj, n_conv, n_dense, quantize,
                                        seed):
    """Every stacked manifest entry's slot path resolves back to its leaf:
    slice j of the bucket array equals the per-leaf array the logical path
    names, and both storage modes expose one logical-path namespace."""
    params = _build_params(n_proj, n_conv, n_dense, seed)
    cfg, state = _stepped_state(params, quantize, seed)
    layout = _layout_for(cfg, params)
    treedef = jax.tree_util.tree_structure(params)
    flat_states = treedef.flatten_up_to(state.leaves)
    stacked = ss.encode(layout, flat_states)
    per_leaf_tree = jax.tree_util.tree_unflatten(treedef, flat_states)

    stacked_entries = ss.manifest_entries({"opt": stacked})
    leaf_entries = ss.manifest_entries({"opt": per_leaf_tree})
    by_path = {e.path: e.value for e in leaf_entries}
    assert all(e.kind == "leaf" for e in leaf_entries)

    logical = set()
    for e in stacked_entries:
        if e.kind == "stacked":
            assert e.slots is not None and len(e.slots) == e.value.shape[0]
            for j, sp in enumerate(e.slots):
                assert sp in by_path, sp
                np.testing.assert_array_equal(
                    np.asarray(e.value[j]), np.asarray(by_path[sp])
                )
                logical.add(sp)
        else:
            assert e.path in by_path
            np.testing.assert_array_equal(
                np.asarray(e.value), np.asarray(by_path[e.path])
            )
            logical.add(e.path)
    # one shared namespace: the stacked walk covers exactly the per-leaf one
    assert logical == set(by_path)


def test_codec_tag_is_v2_and_v1_decodable():
    assert ss.STACKED_CODEC == "stacked-bucket/v2"
    assert ss.STACKED_CODEC_V1 == "stacked-bucket/v1"
    assert ss.DECODABLE_CODECS == {ss.STACKED_CODEC_V1, ss.STACKED_CODEC}
    assert ss.STACKED_STATE_VERSION == 2
