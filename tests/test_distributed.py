"""Multi-device distribution tests (8 host CPU devices via subprocess, so the
main test process keeps its single-device jax). Covers: sharded train step,
cross-pod compressed gradients == uncompressed baseline, elastic checkpoint
reshard 4→8 devices, sharding-rule unit behaviour."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharding_rules_unit():
    """Pure-python rule behaviour (no mesh devices needed beyond 8)."""
    run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as shd
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # standard 2D weight: embed->data, ffn->model
        s = shd.spec_for_axes(("embed", "ffn"), (128, 256), mesh)
        assert s == P("data", "model"), s
        # non-dividing dim falls back to replication
        s = shd.spec_for_axes(("embed", "ffn"), (127, 256), mesh)
        assert s == P(None, "model"), s
        # experts stay local; stacked layers unsharded
        s = shd.spec_for_axes(("layers", "experts", "embed", "ffn"),
                              (4, 8, 128, 256), mesh)
        assert s == P(None, None, "data", "model"), s
        print("rules ok")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """A COAP train step under pjit on a (2,2,2) mesh must equal the
    unsharded step (same params/batch)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.core.api import OptimizerConfig, make_optimizer
        from repro.train.step import make_train_step
        from repro.train.train_state import TrainState
        from repro.distributed import sharding as shd

        cfg = get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        tx = make_optimizer(OptimizerConfig(name="coap-adamw", learning_rate=1e-3,
                                            rank=8, t_update=2, lam=2, min_dim=16))
        params = model.init(jax.random.key(0))
        state = TrainState.create(params, tx)
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        step = make_train_step(model, tx)

        # single-device reference
        ref_state, ref_metrics = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pspecs = model.param_specs(mesh)
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            bspec = shd.batch_specs(batch, mesh)
            bshard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), bspec)
            sharded_batch = jax.device_put(batch, bshard)
            sharded_step = jax.jit(step)
            out_state, out_metrics = sharded_step(state, sharded_batch)
        np.testing.assert_allclose(float(ref_metrics["loss"]),
                                   float(out_metrics["loss"]), rtol=2e-4)
        a = jax.tree_util.tree_leaves(ref_state.params)[3]
        b = jax.tree_util.tree_leaves(out_state.params)[3]
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)
        print("sharded step ok, loss", float(out_metrics["loss"]))
    """)


def test_crosspod_compression_matches_uncompressed():
    """The beyond-paper compressed cross-pod sync must be numerically
    equivalent to all-reducing full gradients (linearity of projection)."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.core.coap_adam import ProjectedAdamConfig, scale_by_projected_adam
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import make_compressed_train_step
        from repro.optim import apply_updates
        from repro.train.train_state import TrainState

        # fp32 so the only difference between paths is the collective
        # schedule, not bf16 reduction order.
        cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        pcfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=16),
            strategy="coap", t_update=2, lam=2, use_fused_kernel=False)
        tx = scale_by_projected_adam(pcfg)
        params = model.init(jax.random.key(0))
        opt_state = tx.init(params)
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        lr = 1e-3

        # Reference: global-batch gradient, plain update.
        def loss_fn(p):
            return model.loss(p, batch)[0]
        grads = jax.grad(loss_fn)(params)
        upd, _ = tx.update(grads, opt_state, params)
        ref_params = apply_updates(
            params, jax.tree_util.tree_map(lambda u: -lr * u, upd))

        # Compressed: 2 pods, per-pod half batches, r-rank cross-pod sync.
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        state = TrainState(step=jnp.zeros([], jnp.int32), params=params,
                           opt_state=opt_state)
        step_fn = make_compressed_train_step(model, pcfg, mesh, lr)
        with mesh:
            bshard = jax.tree_util.tree_map(
                lambda x: jax.device_put(
                    x, NamedSharding(mesh, P("pod"))), batch)
            new_state, metrics = jax.jit(step_fn)(state, bshard)
        for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                        jax.tree_util.tree_leaves(new_state.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)
        print("compression equivalence ok")

        # Stacked state storage: same compressed schedule, moments
        # addressed as bucket slices via the codec's leaf_view — must
        # match the plain-update reference identically.
        scfg = dataclasses.replace(pcfg, stacked_state=True)
        stx = scale_by_projected_adam(scfg)
        sstate = TrainState(step=jnp.zeros([], jnp.int32), params=params,
                            opt_state=stx.init(params))
        sstep_fn = make_compressed_train_step(model, scfg, mesh, lr)
        with mesh:
            snew_state, _ = jax.jit(sstep_fn)(sstate, bshard)
        for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                        jax.tree_util.tree_leaves(snew_state.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)
        print("stacked compression equivalence ok")
    """)


def test_crosspod_conv_compression_matches_uncompressed():
    """Tucker-2 cross-pod compression on a REAL 2-pod mesh: all-reducing
    only the r_O x r_I x K1 x K2 core each step (full G on refresh steps)
    must equal the core transform on the globally averaged gradient — the
    linearity claim a 1-pod mesh (pmean == identity) cannot exercise.
    Multi-step, so eqn6 refresh AND recal steps both cross pods."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import compressed_update

        params = {f"c{i}": 0.01 * jnp.ones((16, 12, 3, 3)) for i in range(2)}
        params["w"] = jnp.zeros((64, 32))
        params["bias"] = jnp.zeros((5,))
        # stagger=False: compression uses the synchronized schedule, so the
        # single-host reference must too (matters beyond step 0).
        cfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
            use_fused_kernel=False, stagger=False)
        tx = scale_by_projected_adam(cfg)

        flat, treedef = jax.tree_util.tree_flatten(params)
        def gtree(seed):
            key = jax.random.key(seed)
            return jax.tree_util.tree_unflatten(treedef, [
                0.1 * jax.random.normal(jax.random.fold_in(key, i), x.shape)
                for i, x in enumerate(flat)])
        g0, g1 = gtree(1), gtree(2)
        g_mean = jax.tree_util.tree_map(lambda a, b: 0.5 * (a + b), g0, g1)

        # Reference: the core transform fed the globally averaged gradient.
        ref_state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(4):  # crosses refresh (t=2) and recal (t=4) steps
            ref_upd, ref_state = step(g_mean, ref_state)

        # Compressed: per-pod gradients, core-only reduction each step.
        mesh = jax.make_mesh((2,), ("pod",),
                             devices=jax.devices()[:2])
        gstack = jax.tree_util.tree_map(
            lambda a, b: jnp.stack([a, b]), g0, g1)
        state = tx.init(params)

        def per_pod(gg, st):
            mine = jax.tree_util.tree_map(lambda x: x[0], gg)
            return compressed_update(cfg, mine, st, "pod")

        mapped = compat.shard_map(
            per_pod, mesh=mesh, in_specs=(P("pod"), P()),
            out_specs=(P(), P()), check_vma=False, axis_names={"pod"})
        for _ in range(4):
            upd, state = jax.jit(mapped)(gstack, state)

        # States integrate the schedule and must agree tightly; raw update
        # directions pass through the Adam normalizer m/(sqrt(v)+eps),
        # which amplifies ulp-level state noise wherever v ~ 0 early in
        # training, so they get the looser (lr-pre-scaling) tolerance the
        # matrix equivalence test applies after lr scaling.
        for a, b in zip(jax.tree_util.tree_leaves(ref_state.leaves),
                        jax.tree_util.tree_leaves(state.leaves)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(ref_upd),
                        jax.tree_util.tree_leaves(upd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=5e-4)
        print("conv cross-pod compression equivalence ok")
    """)


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto an 8-device mesh."""
    run_sub("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tmp = tempfile.mkdtemp()
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        sharded = jax.device_put(w, NamedSharding(mesh4, P("data", "model")))
        state = {"w": sharded, "step": jnp.asarray(7)}
        ckpt.save(tmp, 7, state)

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        template = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        specs = {"w": P("data", "model"), "step": P()}
        restored = ckpt.restore(tmp, template, mesh=mesh8, spec_tree=specs)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.mesh.shape["data"] == 4
        print("elastic reshard ok")
    """)


def test_elastic_checkpoint_reshard_stacked_cross_mode():
    """Save a STACKED optimizer state sharded on a 4-device mesh, restore
    onto an 8-device mesh into BOTH a per-leaf template and a stacked
    template — the codec's logical-path namespace plus elastic device_put."""
    run_sub("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stacked_state as ss
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.train import checkpoint as ckpt

        params = {f"l{i}": {"w": jnp.zeros((64, 32))} for i in range(4)}
        params["bias"] = jnp.zeros((8,))
        flat, treedef = jax.tree_util.tree_flatten(params)
        key = jax.random.key(0)
        g = jax.tree_util.tree_unflatten(treedef, [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)])

        def build(stacked):
            tx = scale_by_projected_adam(ProjectedAdamConfig(
                rules=ProjectionRules(rank=8, min_dim=8), t_update=2,
                lam=2, stacked_state=stacked))
            st = tx.init(params)
            _, st = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, st)
            return tx, st

        tx_s, st_s = build(True)
        tx_p, st_p = build(False)

        mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        from jax.sharding import NamedSharding, PartitionSpec as P
        st_sharded = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh4, P())), st_s)
        tmp = tempfile.mkdtemp()
        ckpt.save(tmp, 1, st_sharded)

        mesh8 = jax.make_mesh((8,), ("data",))
        for tx_dst, want_state, label in [
                (tx_p, st_p, "per-leaf"), (tx_s, st_s, "stacked")]:
            template = jax.eval_shape(lambda: tx_dst.init(params))
            specs = jax.tree_util.tree_map(
                lambda _: P(), template,
                is_leaf=lambda x: hasattr(x, "shape"))
            restored = ckpt.restore(tmp, template, mesh=mesh8,
                                    spec_tree=specs)
            got = restored.leaves
            want = want_state.leaves
            if isinstance(got, ss.StackedLeaves):
                got = ss.decode(got)
                want = ss.decode(want)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=1e-5, atol=2e-6)
            print("reshard restore", label, "ok")
    """)


def test_crosspod_quantized_matches_single_pod():
    """Quantized (int8-state) compressed sync on a REAL 2-pod mesh — the
    dequant->reduce->requant schedule. Where the pod-mean is the identity
    (identical per-pod gradients) the emitted int8 codes must be BIT-EXACT
    against the single-pod quantized step (use_fused_kernel=False oracle
    ops), per-leaf AND stacked layouts. With genuinely different per-pod
    gradients the only drift is the fp32 pmean ordering, bounded by a few
    code steps after requantization (the documented single-rounding rule)."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import stacked_state as ss
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import compressed_update

        params = {"a": 0.01 * jnp.ones((64, 48)),
                  "b": 0.01 * jnp.ones((40, 24)),
                  "c": 0.01 * jnp.ones((16, 12, 3, 3)),
                  "bias": jnp.zeros((5,))}
        cfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
            quantize=True, use_fused_kernel=False, moment_transplant=True)
        tx = scale_by_projected_adam(cfg)
        flat, treedef = jax.tree_util.tree_flatten(params)
        def gtree(seed):
            key = jax.random.key(seed)
            return jax.tree_util.tree_unflatten(treedef, [
                0.1 * jax.random.normal(jax.random.fold_in(key, 31 * seed + i),
                                        x.shape)
                for i, x in enumerate(flat)])

        mesh = jax.make_mesh((2,), ("pod",), devices=jax.devices()[:2])
        def run_compressed(ccfg, gstack_of, steps=4):
            state = scale_by_projected_adam(ccfg).init(params)
            def per_pod(gg, st):
                mine = jax.tree_util.tree_map(lambda x: x[0], gg)
                return compressed_update(ccfg, mine, st, "pod")
            mapped = compat.shard_map(
                per_pod, mesh=mesh, in_specs=(P("pod"), P()),
                out_specs=(P(), P()), check_vma=False, axis_names={"pod"})
            upd = None
            for s in range(steps):
                upd, state = jax.jit(mapped)(gstack_of(s), state)
            return upd, state

        # Single-pod reference (the core transform, unfused oracle ops).
        ref_state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for s in range(4):
            ref_upd, ref_state = step(gtree(s), ref_state)

        # --- pmean == identity: BIT-EXACT codes, per-leaf layout.
        same = lambda s: jax.tree_util.tree_map(
            lambda x: jnp.stack([x, x]), gtree(s))
        upd, state = run_compressed(cfg, same)
        def assert_exact(leaves_a, leaves_b, label):
            fa = jax.tree_util.tree_leaves_with_path(leaves_a)
            fb = jax.tree_util.tree_leaves_with_path(leaves_b)
            assert len(fa) == len(fb)
            for (pa, a), (pb, b) in zip(fa, fb):
                a, b = np.asarray(a), np.asarray(b)
                if a.dtype == np.int8:
                    np.testing.assert_array_equal(a, b,
                        err_msg=f"{label}:{jax.tree_util.keystr(pa)}")
                else:
                    np.testing.assert_allclose(
                        a.astype(np.float32), b.astype(np.float32),
                        rtol=1e-6, atol=1e-7,
                        err_msg=f"{label}:{jax.tree_util.keystr(pa)}")
        assert_exact(ref_state.leaves, state.leaves, "per-leaf")
        for a, b in zip(jax.tree_util.tree_leaves(ref_upd),
                        jax.tree_util.tree_leaves(upd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        print("quantized bit-exact per-leaf ok")

        # --- stacked layout: same schedule addressed as bucket slices.
        scfg = dataclasses.replace(cfg, stacked_state=True)
        supd, sstate = run_compressed(scfg, same)
        assert isinstance(sstate.leaves, ss.StackedLeaves)
        assert_exact(ref_state.leaves, ss.decode(sstate.leaves), "stacked")
        print("quantized bit-exact stacked ok")

        # --- different per-pod gradients: project(pmean(G)) vs
        # pmean(project(G)) differ only in fp32 summation order, so after
        # requantization the codes sit within a few code steps (one
        # rounding per step, geometrically damped by b1 across steps).
        def gpair(s):
            g0, g1 = gtree(10 + s), gtree(20 + s)
            return jax.tree_util.tree_map(
                lambda a, b: jnp.stack([a, b]), g0, g1)
        ref2 = tx.init(params)
        for s in range(4):
            g0, g1 = gtree(10 + s), gtree(20 + s)
            gm = jax.tree_util.tree_map(lambda a, b: 0.5 * (a + b), g0, g1)
            ref2_upd, ref2 = step(gm, ref2)
        dupd, dstate = run_compressed(cfg, gpair)
        fa = jax.tree_util.tree_leaves_with_path(ref2.leaves)
        fb = jax.tree_util.tree_leaves_with_path(dstate.leaves)
        for (pa, a), (pb, b) in zip(fa, fb):
            a, b = np.asarray(a), np.asarray(b)
            if a.dtype == np.int8:
                diff = np.abs(a.astype(np.int32) - b.astype(np.int32))
                assert diff.max() <= 3, (jax.tree_util.keystr(pa), diff.max())
            else:
                np.testing.assert_allclose(
                    a.astype(np.float32), b.astype(np.float32),
                    rtol=5e-3, atol=5e-4,
                    err_msg=jax.tree_util.keystr(pa))
        for a, b in zip(jax.tree_util.tree_leaves(ref2_upd),
                        jax.tree_util.tree_leaves(dupd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-2, atol=5e-3)
        print("quantized drift bound ok")
    """)


def test_crosspod_sync_codes_int8_collective():
    """The sync_codes wire path on a REAL 2-pod mesh. (1) Telescoping
    invariant of the raw collective: with constant per-pod inputs,
    sum_t(applied_t) == T*mean + ef_0 - ef_T to fp32 rounding — the int8
    rounding residue never accumulates. (2) The EF accumulator stays bounded by one
    code step forever, so the error in the applied time-average drains to
    zero as 1/T on constant gradients. (3) End-to-end compressed training with
    sync_codes=True tracks the fp32-sync run, with a live EF sidecar."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import (
            _allreduce_codes, compressed_update)
        from repro.optim import apply_updates

        mesh = jax.make_mesh((2,), ("pod",), devices=jax.devices()[:2])
        T, BLOCK = 12, 32
        xs = jax.random.normal(jax.random.key(0), (2, 4, 96))

        def collective(xstack):
            x = xstack[0]
            ef = jnp.zeros_like(x)
            acc = jnp.zeros_like(x)
            efs = []
            for _ in range(T):
                red, ef = _allreduce_codes(x, ef, "pod", BLOCK)
                acc = acc + red
                efs.append(ef)
            return acc, efs[-2], efs[-1], red

        mapped = compat.shard_map(
            collective, mesh=mesh, in_specs=(P("pod"),),
            out_specs=(P(), P(), P(), P()), check_vma=False,
            axis_names={"pod"})
        acc, ef_prev, ef_last, red_last = jax.jit(mapped)(xs)
        mean = np.asarray(jnp.mean(xs, 0))
        # telescoping: rounding residue ends in ef, never in the sum
        np.testing.assert_allclose(
            np.asarray(acc) + np.asarray(ef_last), T * mean,
            rtol=1e-5, atol=1e-5)
        # The accumulator never grows: |ef| stays bounded by ONE code
        # step (the shared block scale) for all time — rounding error
        # drains into a bounded residual instead of accumulating. (It
        # orbits inside that bound rather than hitting a pointwise fixed
        # point: the shared-scale rounding is a small cycle, not a
        # contraction.)
        bound = (np.abs(np.asarray(xs)).max()
                 + np.abs(np.asarray(ef_last)).max()) / 127.0
        for e in (ef_prev, ef_last):
            assert np.abs(np.asarray(e)).max() <= bound * 1.01
        # ... so the error in the APPLIED time-average drains to zero as
        # 1/T on constant gradients (the telescoping sum, per element):
        assert np.abs(np.asarray(acc) / T - mean).max() <= (
            2.0 * bound / T) * 1.01
        # single-rounding per-step bound: |applied - mean| <= block scale
        assert np.abs(np.asarray(red_last) - mean).max() <= bound * 1.01
        print("collective telescoping ok")

        # --- end-to-end: sync_codes tracks the fp32 sync run.
        params = {"a": 0.01 * jnp.ones((64, 48)),
                  "c": 0.01 * jnp.ones((16, 12, 3, 3)),
                  "bias": jnp.zeros((5,))}
        base = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=100, lam=2,
            use_fused_kernel=False)
        flat, treedef = jax.tree_util.tree_flatten(params)
        key = jax.random.key(3)
        gstack = jax.tree_util.tree_map(
            lambda x: jnp.stack([x, 1.5 * x]),
            jax.tree_util.tree_unflatten(treedef, [
                0.1 * jax.random.normal(jax.random.fold_in(key, i), x.shape)
                for i, x in enumerate(flat)]))

        def train(ccfg, steps=6, lr=0.01):
            state = scale_by_projected_adam(ccfg).init(params)
            p = params
            def per_pod(gg, st):
                mine = jax.tree_util.tree_map(lambda x: x[0], gg)
                return compressed_update(ccfg, mine, st, "pod")
            mapped = compat.shard_map(
                per_pod, mesh=mesh, in_specs=(P("pod"), P()),
                out_specs=(P(), P()), check_vma=False, axis_names={"pod"})
            for _ in range(steps):
                upd, state = jax.jit(mapped)(gstack, state)
                p = apply_updates(p, jax.tree_util.tree_map(
                    lambda u: -lr * u, upd))
            return p, state

        p_ref, st_ref = train(base)
        p_q, st_q = train(dataclasses.replace(base, sync_codes=True))
        assert st_ref.leaves["a"].ef is None
        ef = st_q.leaves["a"].ef
        assert ef is not None and bool(jnp.all(jnp.isfinite(ef)))
        # constant gradients + frozen P (T_u=100): EF stabilizes
        assert st_q.leaves["c"].ef is not None
        # Training-trajectory tolerance, not parity: the EF collective
        # corrects the TIME-AVERAGE of g_proj, but Adam's m/(sqrt(v)+eps)
        # normalizer is nonlinear in the moments, so per-element drift can
        # reach a few lr-steps where v ~ 0 early in training. Bound the
        # drift at a few lr-steps per element, and require the overall
        # trajectories to agree to ~10% in norm (measured ~7.7% here).
        for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                        jax.tree_util.tree_leaves(p_q)):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            np.testing.assert_allclose(a, b, rtol=0, atol=3e-2)
            assert np.linalg.norm(a - b) <= 0.12 * max(
                np.linalg.norm(a), 1e-3)
        print("sync_codes end-to-end ok")
    """)


# ---------------------------------------------------------------------------
# Schedule-parity and validation tests: pmean over a 1-pod mesh is the
# identity, so these run in the main (single-device) process and pin the
# SCHEDULE, not the collective.
# ---------------------------------------------------------------------------
def _compressed_runner(cfg, params):
    """compressed_update wrapped in a 1-pod shard_map (pmean == identity)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.distributed.compression import compressed_update

    mesh = jax.make_mesh((1,), ("pod",))
    return compat.shard_map(
        lambda gg, st: compressed_update(cfg, gg, st, "pod"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False, axis_names={"pod"},
    )


def _stagger_tree():
    import jax.numpy as jnp

    params = {f"l{i}": {"w": 0.01 * jnp.ones((32, 24))} for i in range(4)}
    params["solo"] = jnp.zeros((40, 16))
    params["bias"] = jnp.zeros((5,))
    return params


def _gtree(params, seed):
    import jax

    flat, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.key(seed)
    return jax.tree_util.tree_unflatten(treedef, [
        0.1 * jax.random.normal(jax.random.fold_in(key, 31 * seed + i),
                                x.shape)
        for i, x in enumerate(flat)])


def test_compressed_stagger_cadence_matches_core():
    """Regression for the silent-desync bug: with stagger on, the
    compressed path must refresh each leaf on EXACTLY the steps the core
    transform does (shared bucket_phases allocation), and the phase groups
    must actually differ — not collapse back to the synchronized
    schedule."""
    import jax
    import numpy as np

    from repro.core.coap_adam import (
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.core.projector import ProjectionRules

    params = _stagger_tree()
    # T_u=4 with 3 stagger units (2 for the l-bucket + 1 for solo) spreads
    # phases 0/1/2 — the l-bucket genuinely splits across two phases.
    cfg = ProjectedAdamConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=4, lam=2,
        stagger=True, stagger_groups=2, use_fused_kernel=False)
    tx = scale_by_projected_adam(cfg)
    ref_state = tx.init(params)
    state = tx.init(params)
    step_ref = jax.jit(lambda gg, s: tx.update(gg, s, None))
    step_cmp = jax.jit(_compressed_runner(cfg, params))

    names = [f"l{i}" for i in range(4)] + ["solo"]

    def p_of(s, name):
        leaf = s.leaves[name]["w"] if name.startswith("l") else s.leaves[name]
        return np.asarray(leaf.p)

    prev_ref = {n: p_of(ref_state, n) for n in names}
    prev_cmp = {n: p_of(state, n) for n in names}
    changed_ref = {n: [] for n in names}
    changed_cmp = {n: [] for n in names}
    for s in range(9):
        g = _gtree(params, s)
        ru, ref_state = step_ref(g, ref_state)
        cu, state = step_cmp(g, state)
        for a, b in zip(jax.tree_util.tree_leaves(ru),
                        jax.tree_util.tree_leaves(cu)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)
        for n in names:
            now_r, now_c = p_of(ref_state, n), p_of(state, n)
            changed_ref[n].append(not np.array_equal(prev_ref[n], now_r))
            changed_cmp[n].append(not np.array_equal(prev_cmp[n], now_c))
            prev_ref[n], prev_cmp[n] = now_r, now_c
    # cadence parity, leaf by leaf
    for n in names:
        assert changed_cmp[n] == changed_ref[n], (
            n, changed_cmp[n], changed_ref[n])
    # stagger is ACTIVE: the congruent bucket spans >1 refresh pattern
    patterns = {tuple(changed_cmp[f"l{i}"]) for i in range(4)}
    assert len(patterns) > 1, patterns


def test_compressed_per_bucket_t_update_override_matches_core():
    """Per-bucket T_u overrides run natively through the compressed
    schedule (no rejection), at the overridden cadence, matching the core
    transform — including a reordered entries container that restates the
    global value for another leaf."""
    import dataclasses

    import jax
    import numpy as np

    from repro.core.coap_adam import (
        LeafOverrides,
        PlanOverrides,
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.core.projector import ProjectionRules

    params = _stagger_tree()
    base = ProjectedAdamConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
        stagger=True, stagger_groups=2, use_fused_kernel=False)
    # the l-bucket pinned to T_u=4; solo restates the global T_u=2;
    # entries deliberately out of tree order.
    cfg = dataclasses.replace(base, overrides=PlanOverrides(entries=(
        ("l2/w", LeafOverrides(t_update=4)),
        ("solo", LeafOverrides(t_update=2)),
        ("l0/w", LeafOverrides(t_update=4)),
        ("l3/w", LeafOverrides(t_update=4)),
        ("l1/w", LeafOverrides(t_update=4)),
    )))
    tx = scale_by_projected_adam(cfg)
    ref_state = tx.init(params)
    state = tx.init(params)
    step_ref = jax.jit(lambda gg, s: tx.update(gg, s, None))
    step_cmp = jax.jit(_compressed_runner(cfg, params))
    changed = {n: [] for n in ["l0", "solo"]}
    prev = {"l0": np.asarray(state.leaves["l0"]["w"].p),
            "solo": np.asarray(state.leaves["solo"].p)}
    for s in range(8):
        g = _gtree(params, s)
        ru, ref_state = step_ref(g, ref_state)
        cu, state = step_cmp(g, state)
        for a, b in zip(jax.tree_util.tree_leaves(ru),
                        jax.tree_util.tree_leaves(cu)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)
        now = {"l0": np.asarray(state.leaves["l0"]["w"].p),
               "solo": np.asarray(state.leaves["solo"].p)}
        for n in changed:
            changed[n].append(not np.array_equal(prev[n], now[n]))
            prev[n] = now[n]
    # overridden bucket refreshes every 4 steps, solo every 2 — distinct
    # cadences from ONE config (the old code rejected this outright).
    assert sum(changed["l0"]) < sum(changed["solo"]), changed
    assert sum(changed["l0"]) >= 2, changed  # it does refresh


def test_compressed_perleaf_reordered_state_raises():
    """Regression (per-leaf branch of the signature check): a congruent-
    but-reordered state tree must raise, never silently pair moments with
    the wrong leaves."""
    import jax.numpy as jnp
    import pytest as _pytest

    from repro.core.coap_adam import (
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.core.projector import ProjectionRules

    params = {"a": jnp.zeros((64, 32)), "b": jnp.zeros((48, 16))}
    cfg = ProjectedAdamConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
        use_fused_kernel=False)
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    swapped = state._replace(
        leaves={"a": state.leaves["b"], "b": state.leaves["a"]})
    g = _gtree(params, 0)
    runner = _compressed_runner(cfg, params)
    with _pytest.raises(ValueError, match="does not match the gradient"):
        runner(g, swapped)


def test_compressed_sync_codes_requires_ef_sidecar():
    """sync_codes=True against a state initialized without the EF sidecar
    must fail loudly (re-init/migrate, don't silently skip compensation)."""
    import dataclasses

    import jax.numpy as jnp
    import pytest as _pytest

    from repro.core.coap_adam import (
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.core.projector import ProjectionRules

    params = {"a": jnp.zeros((64, 32))}
    cfg = ProjectedAdamConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
        use_fused_kernel=False)
    state = scale_by_projected_adam(cfg).init(params)
    assert state.leaves["a"].ef is None
    ecfg = dataclasses.replace(cfg, sync_codes=True)
    runner = _compressed_runner(ecfg, params)
    with _pytest.raises(ValueError, match="error-feedback"):
        runner(_gtree(params, 0), state)
