"""Multi-device distribution tests (8 host CPU devices via subprocess, so the
main test process keeps its single-device jax). Covers: sharded train step,
cross-pod compressed gradients == uncompressed baseline, elastic checkpoint
reshard 4→8 devices, sharding-rule unit behaviour."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharding_rules_unit():
    """Pure-python rule behaviour (no mesh devices needed beyond 8)."""
    run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as shd
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # standard 2D weight: embed->data, ffn->model
        s = shd.spec_for_axes(("embed", "ffn"), (128, 256), mesh)
        assert s == P("data", "model"), s
        # non-dividing dim falls back to replication
        s = shd.spec_for_axes(("embed", "ffn"), (127, 256), mesh)
        assert s == P(None, "model"), s
        # experts stay local; stacked layers unsharded
        s = shd.spec_for_axes(("layers", "experts", "embed", "ffn"),
                              (4, 8, 128, 256), mesh)
        assert s == P(None, None, "data", "model"), s
        print("rules ok")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """A COAP train step under pjit on a (2,2,2) mesh must equal the
    unsharded step (same params/batch)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.core.api import OptimizerConfig, make_optimizer
        from repro.train.step import make_train_step
        from repro.train.train_state import TrainState
        from repro.distributed import sharding as shd

        cfg = get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        tx = make_optimizer(OptimizerConfig(name="coap-adamw", learning_rate=1e-3,
                                            rank=8, t_update=2, lam=2, min_dim=16))
        params = model.init(jax.random.key(0))
        state = TrainState.create(params, tx)
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        step = make_train_step(model, tx)

        # single-device reference
        ref_state, ref_metrics = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pspecs = model.param_specs(mesh)
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            bspec = shd.batch_specs(batch, mesh)
            bshard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), bspec)
            sharded_batch = jax.device_put(batch, bshard)
            sharded_step = jax.jit(step)
            out_state, out_metrics = sharded_step(state, sharded_batch)
        np.testing.assert_allclose(float(ref_metrics["loss"]),
                                   float(out_metrics["loss"]), rtol=2e-4)
        a = jax.tree_util.tree_leaves(ref_state.params)[3]
        b = jax.tree_util.tree_leaves(out_state.params)[3]
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)
        print("sharded step ok, loss", float(out_metrics["loss"]))
    """)


def test_crosspod_compression_matches_uncompressed():
    """The beyond-paper compressed cross-pod sync must be numerically
    equivalent to all-reducing full gradients (linearity of projection)."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.core.coap_adam import ProjectedAdamConfig, scale_by_projected_adam
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import make_compressed_train_step
        from repro.optim import apply_updates
        from repro.train.train_state import TrainState

        # fp32 so the only difference between paths is the collective
        # schedule, not bf16 reduction order.
        cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        pcfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=16),
            strategy="coap", t_update=2, lam=2, use_fused_kernel=False)
        tx = scale_by_projected_adam(pcfg)
        params = model.init(jax.random.key(0))
        opt_state = tx.init(params)
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        lr = 1e-3

        # Reference: global-batch gradient, plain update.
        def loss_fn(p):
            return model.loss(p, batch)[0]
        grads = jax.grad(loss_fn)(params)
        upd, _ = tx.update(grads, opt_state, params)
        ref_params = apply_updates(
            params, jax.tree_util.tree_map(lambda u: -lr * u, upd))

        # Compressed: 2 pods, per-pod half batches, r-rank cross-pod sync.
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        state = TrainState(step=jnp.zeros([], jnp.int32), params=params,
                           opt_state=opt_state)
        step_fn = make_compressed_train_step(model, pcfg, mesh, lr)
        with mesh:
            bshard = jax.tree_util.tree_map(
                lambda x: jax.device_put(
                    x, NamedSharding(mesh, P("pod"))), batch)
            new_state, metrics = jax.jit(step_fn)(state, bshard)
        for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                        jax.tree_util.tree_leaves(new_state.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)
        print("compression equivalence ok")

        # Stacked state storage: same compressed schedule, moments
        # addressed as bucket slices via the codec's leaf_view — must
        # match the plain-update reference identically.
        scfg = dataclasses.replace(pcfg, stacked_state=True)
        stx = scale_by_projected_adam(scfg)
        sstate = TrainState(step=jnp.zeros([], jnp.int32), params=params,
                            opt_state=stx.init(params))
        sstep_fn = make_compressed_train_step(model, scfg, mesh, lr)
        with mesh:
            snew_state, _ = jax.jit(sstep_fn)(sstate, bshard)
        for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                        jax.tree_util.tree_leaves(snew_state.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)
        print("stacked compression equivalence ok")
    """)


def test_crosspod_conv_compression_matches_uncompressed():
    """Tucker-2 cross-pod compression on a REAL 2-pod mesh: all-reducing
    only the r_O x r_I x K1 x K2 core each step (full G on refresh steps)
    must equal the core transform on the globally averaged gradient — the
    linearity claim a 1-pod mesh (pmean == identity) cannot exercise.
    Multi-step, so eqn6 refresh AND recal steps both cross pods."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.distributed.compression import compressed_update

        params = {f"c{i}": 0.01 * jnp.ones((16, 12, 3, 3)) for i in range(2)}
        params["w"] = jnp.zeros((64, 32))
        params["bias"] = jnp.zeros((5,))
        # stagger=False: compression uses the synchronized schedule, so the
        # single-host reference must too (matters beyond step 0).
        cfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
            use_fused_kernel=False, stagger=False)
        tx = scale_by_projected_adam(cfg)

        flat, treedef = jax.tree_util.tree_flatten(params)
        def gtree(seed):
            key = jax.random.key(seed)
            return jax.tree_util.tree_unflatten(treedef, [
                0.1 * jax.random.normal(jax.random.fold_in(key, i), x.shape)
                for i, x in enumerate(flat)])
        g0, g1 = gtree(1), gtree(2)
        g_mean = jax.tree_util.tree_map(lambda a, b: 0.5 * (a + b), g0, g1)

        # Reference: the core transform fed the globally averaged gradient.
        ref_state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(4):  # crosses refresh (t=2) and recal (t=4) steps
            ref_upd, ref_state = step(g_mean, ref_state)

        # Compressed: per-pod gradients, core-only reduction each step.
        mesh = jax.make_mesh((2,), ("pod",),
                             devices=jax.devices()[:2])
        gstack = jax.tree_util.tree_map(
            lambda a, b: jnp.stack([a, b]), g0, g1)
        state = tx.init(params)

        def per_pod(gg, st):
            mine = jax.tree_util.tree_map(lambda x: x[0], gg)
            return compressed_update(cfg, mine, st, "pod")

        mapped = compat.shard_map(
            per_pod, mesh=mesh, in_specs=(P("pod"), P()),
            out_specs=(P(), P()), check_vma=False, axis_names={"pod"})
        for _ in range(4):
            upd, state = jax.jit(mapped)(gstack, state)

        # States integrate the schedule and must agree tightly; raw update
        # directions pass through the Adam normalizer m/(sqrt(v)+eps),
        # which amplifies ulp-level state noise wherever v ~ 0 early in
        # training, so they get the looser (lr-pre-scaling) tolerance the
        # matrix equivalence test applies after lr scaling.
        for a, b in zip(jax.tree_util.tree_leaves(ref_state.leaves),
                        jax.tree_util.tree_leaves(state.leaves)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(ref_upd),
                        jax.tree_util.tree_leaves(upd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=5e-4)
        print("conv cross-pod compression equivalence ok")
    """)


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto an 8-device mesh."""
    run_sub("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tmp = tempfile.mkdtemp()
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        sharded = jax.device_put(w, NamedSharding(mesh4, P("data", "model")))
        state = {"w": sharded, "step": jnp.asarray(7)}
        ckpt.save(tmp, 7, state)

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        template = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        specs = {"w": P("data", "model"), "step": P()}
        restored = ckpt.restore(tmp, template, mesh=mesh8, spec_tree=specs)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.mesh.shape["data"] == 4
        print("elastic reshard ok")
    """)


def test_elastic_checkpoint_reshard_stacked_cross_mode():
    """Save a STACKED optimizer state sharded on a 4-device mesh, restore
    onto an 8-device mesh into BOTH a per-leaf template and a stacked
    template — the codec's logical-path namespace plus elastic device_put."""
    run_sub("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stacked_state as ss
        from repro.core.coap_adam import (
            ProjectedAdamConfig, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.train import checkpoint as ckpt

        params = {f"l{i}": {"w": jnp.zeros((64, 32))} for i in range(4)}
        params["bias"] = jnp.zeros((8,))
        flat, treedef = jax.tree_util.tree_flatten(params)
        key = jax.random.key(0)
        g = jax.tree_util.tree_unflatten(treedef, [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)])

        def build(stacked):
            tx = scale_by_projected_adam(ProjectedAdamConfig(
                rules=ProjectionRules(rank=8, min_dim=8), t_update=2,
                lam=2, stacked_state=stacked))
            st = tx.init(params)
            _, st = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, st)
            return tx, st

        tx_s, st_s = build(True)
        tx_p, st_p = build(False)

        mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        from jax.sharding import NamedSharding, PartitionSpec as P
        st_sharded = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh4, P())), st_s)
        tmp = tempfile.mkdtemp()
        ckpt.save(tmp, 1, st_sharded)

        mesh8 = jax.make_mesh((8,), ("data",))
        for tx_dst, want_state, label in [
                (tx_p, st_p, "per-leaf"), (tx_s, st_s, "stacked")]:
            template = jax.eval_shape(lambda: tx_dst.init(params))
            specs = jax.tree_util.tree_map(
                lambda _: P(), template,
                is_leaf=lambda x: hasattr(x, "shape"))
            restored = ckpt.restore(tmp, template, mesh=mesh8,
                                    spec_tree=specs)
            got = restored.leaves
            want = want_state.leaves
            if isinstance(got, ss.StackedLeaves):
                got = ss.decode(got)
                want = ss.decode(want)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    rtol=1e-5, atol=2e-6)
            print("reshard restore", label, "ok")
    """)
