"""Process-isolated supervision: the exec worker model.

Covers the escalation ladder (``decide_supervision``), the
ProcessSupervisor's heartbeat-only kill/drain decisions against FAKE
worker processes (no jax boot — fast), and ONE full end-to-end run with
real ``launch/worker.py`` subprocesses: SIGKILL mid-run, topology shrink
8→4, preemption-notice drain with zero lost steps, convergence to the
in-process baseline.
"""
import json
import os
import sys
import textwrap
import time

import pytest

from repro.train.elastic import (
    EXIT_DRAINED,
    ElasticConfig,
    ProcessSupervisor,
    ProcessSupervisorConfig,
    Topology,
    read_events,
)
from repro.train.fault_tolerance import SupervisionPolicy, decide_supervision


# ---------------------------------------------------------------------------
# The escalation ladder, as a pure function
# ---------------------------------------------------------------------------
def test_decide_supervision_ladder():
    pol = SupervisionPolicy(start_grace_s=10.0, stale_grace_s=1.0,
                            straggler_drain_after=3)
    # missing: grace, then kill
    assert decide_supervision("missing", missing_for_s=5.0, policy=pol) == "wait"
    assert decide_supervision("missing", missing_for_s=11.0, policy=pol) == "kill"
    # stale: grace, then kill
    assert decide_supervision("stale", stale_for_s=0.5, policy=pol) == "wait"
    assert decide_supervision("stale", stale_for_s=1.5, policy=pol) == "kill"
    # alive: ok until enough straggler evidence, then drain
    assert decide_supervision("alive", straggler_flagged=2, policy=pol) == "ok"
    assert decide_supervision("alive", straggler_flagged=3, policy=pol) == "drain"
    # straggler_drain_after=0 disables draining entirely
    off = SupervisionPolicy(straggler_drain_after=0)
    assert decide_supervision("alive", straggler_flagged=99, policy=off) == "ok"
    with pytest.raises(ValueError):
        decide_supervision("zombie")


# ---------------------------------------------------------------------------
# ProcessSupervisor vs fake workers (no jax — exercises the watch loop)
# ---------------------------------------------------------------------------
def _fake_cmd(body: str):
    """A worker stand-in: a python -c script speaking the file protocol
    (heartbeat / notice+ack / DONE / exit codes) without booting jax."""
    prelude = textwrap.dedent(
        """\
        import json, os, sys, time
        hb = os.environ["FAKE_HB"]
        notice = os.environ["FAKE_NOTICE"]
        done = os.environ["FAKE_DONE"]
        attempt = int(os.environ.get("REPRO_WORKER_ATTEMPT", "0"))
        def beat(step, **extra):
            payload = {"step": step, "time": time.time()}
            payload.update(extra)
            with open(hb, "w") as f:
                json.dump(payload, f)
        """
    )
    return [sys.executable, "-c", prelude + textwrap.dedent(body)]


def _psup(tmp_path, body, policy, *, heartbeat_timeout_s=0.4,
          fault_injector=None, total_steps=12):
    d = str(tmp_path)
    cfg = ElasticConfig(
        ckpt_dir=d, total_steps=total_steps,
        topology=(Topology(8, 10**9),),
        heartbeat_timeout_s=heartbeat_timeout_s,
        backoff_base=0.0,
    )
    pcfg = ProcessSupervisorConfig(
        poll_interval_s=0.02, policy=policy, drain_deadline_s=5.0,
        worker_cmd=_fake_cmd(body),
        spawn_env={
            "FAKE_HB": os.path.join(d, "heartbeat.json"),
            "FAKE_NOTICE": os.path.join(d, "notice.json"),
            "FAKE_DONE": os.path.join(d, "DONE.json"),
        },
    )
    return ProcessSupervisor({}, cfg, pcfg, fault_injector=fault_injector)


def test_stale_worker_is_killed_and_relaunched(tmp_path):
    """Attempt 0 beats, then wedges (stops beating while the process
    lives): the supervisor declares death on the STALE heartbeat alone,
    SIGKILLs, and relaunches; attempt 1 completes."""
    body = """\
        if attempt >= 1:
            with open(done, "w") as f:
                json.dump({"step": 12, "loss": 1.25, "attempt": attempt}, f)
            sys.exit(0)
        for s in range(3):
            beat(s)
            time.sleep(0.05)
        time.sleep(120)  # wedged: alive but silent -> supervisor must kill
        """
    sup = _psup(tmp_path, body,
                SupervisionPolicy(start_grace_s=30.0, stale_grace_s=0.2))
    t0 = time.time()
    done = sup.run()
    assert done == {"step": 12, "loss": 1.25, "attempt": 1}
    assert time.time() - t0 < 30  # killed the wedge, did not wait it out
    kinds = [e[0] for e in sup.events]
    assert kinds.count("spawn") == 2
    assert "crash" in kinds and "done" in kinds
    crash = next(e for e in sup.events if e[0] == "crash")
    assert crash[2]["heartbeat"] == "stale"  # death declared via heartbeat


def test_missing_heartbeat_past_grace_is_killed(tmp_path):
    """Attempt 0 never beats at all: past start_grace_s the supervisor
    presumes dead-on-arrival and restarts."""
    body = """\
        if attempt >= 1:
            with open(done, "w") as f:
                json.dump({"step": 5, "loss": 2.0, "attempt": attempt}, f)
            sys.exit(0)
        time.sleep(120)  # boots, never heartbeats
        """
    sup = _psup(tmp_path, body,
                SupervisionPolicy(start_grace_s=0.3, stale_grace_s=0.2))
    done = sup.run()
    assert done["attempt"] == 1
    crash = next(e for e in sup.events if e[0] == "crash")
    assert crash[2]["heartbeat"] == "missing"


def test_straggler_beats_trigger_drain_not_kill(tmp_path):
    """The worker's beats carry straggler evidence: the supervisor DRAINS
    (notice → ack → EXIT_DRAINED) instead of killing — clean handoff, no
    crash recorded, immediate relaunch."""
    body = """\
        if attempt >= 1:
            with open(done, "w") as f:
                json.dump({"step": 7, "loss": 0.5, "attempt": attempt}, f)
            sys.exit(0)
        step = 0
        while True:
            beat(step, straggler_flagged=2)
            if os.path.exists(notice):
                with open(notice + ".ack", "w") as f:
                    json.dump({"step": step, "time": time.time()}, f)
                sys.exit(75)
            step += 1
            time.sleep(0.03)
        """
    sup = _psup(tmp_path, body,
                SupervisionPolicy(start_grace_s=30.0, stale_grace_s=0.2,
                                  straggler_drain_after=2))
    done = sup.run()
    assert done["attempt"] == 1
    kinds = [e[0] for e in sup.events]
    assert "drain_notice" in kinds and "drained" in kinds
    assert "crash" not in kinds  # a drain is a handoff, not a crash
    drained = next(e for e in sup.events if e[0] == "drained")
    assert drained[2].get("step", -1) >= 0  # ack payload propagated
    assert sup.events[-1][0] == "done"
    assert 75 == EXIT_DRAINED


def test_crash_budget_stops_a_crash_loop(tmp_path):
    """A worker that dies instantly every attempt exhausts the sliding
    crash budget and the supervisor gives up with a RuntimeError."""
    body = """\
        sys.exit(3)  # immediate crash, every attempt
        """
    d = str(tmp_path)
    cfg = ElasticConfig(
        ckpt_dir=d, total_steps=12, topology=(Topology(8, 10**9),),
        heartbeat_timeout_s=0.4, backoff_base=0.0, max_crashes=2,
    )
    pcfg = ProcessSupervisorConfig(
        poll_interval_s=0.02,
        policy=SupervisionPolicy(start_grace_s=0.1, stale_grace_s=0.1),
        worker_cmd=_fake_cmd(body),
        spawn_env={"FAKE_HB": os.path.join(d, "hb.json"),
                   "FAKE_NOTICE": os.path.join(d, "n.json"),
                   "FAKE_DONE": os.path.join(d, "d.json")},
    )
    sup = ProcessSupervisor({}, cfg, pcfg)
    with pytest.raises(RuntimeError, match="crash budget"):
        sup.run()
    assert [e[0] for e in sup.events].count("crash") == 3


# ---------------------------------------------------------------------------
# THE e2e: real worker subprocesses, real SIGKILL, shrink, drain, converge
# ---------------------------------------------------------------------------
def test_process_worker_sigkill_shrink_drain_converges(tmp_path):
    """Full acceptance scenario, out of process:

    * attempt 0 (8 devices) is SIGKILLed for real once its heartbeat
      reports step >= 7 — the supervisor acts on heartbeat staleness, not
      the exit status;
    * attempt 1 replans on the shrunk topology (4 devices, the step-6
      checkpoint migrates), then receives an injected preemption NOTICE
      at step >= 9: it checkpoints at its exact current step, acks and
      exits EXIT_DRAINED before the deadline;
    * attempt 2 resumes from the drained checkpoint with ZERO lost steps
      and runs to completion; final loss matches the uninterrupted
      in-process baseline.
    """
    from repro.configs import get_smoke
    from repro.core.api import OptimizerConfig
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build_model
    from repro.plan.solver import solve_for_topology
    from repro.train.elastic import ElasticSupervisor
    from repro.train.faults import FaultInjector, FaultSchedule

    kw = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)
    mcfg = get_smoke("tinyllama-1.1b")
    model = build_model(mcfg)
    params = model.abstract_params()
    h32 = solve_for_topology(params, 1, 10**12, quantize="off",
                             **kw).predicted["hbm_total_bytes"]
    h8 = solve_for_topology(params, 1, 10**12, quantize="force",
                            **kw).predicted["hbm_total_bytes"]
    per_dev = (h32 + h8) // 2 // 4  # 8 devs fit fp32, 4 devs force int8

    # In-process uninterrupted baseline (8 devices, 12 steps).
    data = SyntheticLM(vocab=mcfg.vocab_size, order=1, noise=0.2)
    batch_fn = lambda step, host: data.batch(step, batch=4, seq=16, host=host)
    base_cfg = ElasticConfig(
        ckpt_dir=str(tmp_path / "base"), total_steps=12,
        topology=(Topology(8, per_dev),), solve_kw=kw,
        ckpt_every=2, log_every=100, backoff_base=0.0,
    )
    base = ElasticSupervisor(
        model, batch_fn, base_cfg,
        ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
    )
    state_base = base.run()
    loss_base, _ = model.loss(state_base.params, batch_fn(13, 0))

    # The out-of-process run.
    d = str(tmp_path / "proc")
    cfg = ElasticConfig(
        ckpt_dir=d, total_steps=12,
        topology=(Topology(8, per_dev), Topology(4, per_dev, from_step=6)),
        solve_kw=kw, ckpt_every=2, log_every=100, backoff_base=0.0,
        min_step_s=0.25,           # pace steps so supervision races are real
        heartbeat_interval_s=0.1,  # liveness = process-liveness
        heartbeat_timeout_s=1.0,
    )
    inj = FaultInjector(
        FaultSchedule(kill_at=(7,), notice_at=((9, 8.0),)), seed=0
    )
    pcfg = ProcessSupervisorConfig(
        poll_interval_s=0.05,
        policy=SupervisionPolicy(start_grace_s=300.0, stale_grace_s=0.3),
    )
    sup = ProcessSupervisor(
        {"arch": "tinyllama-1.1b", "smoke": True, "optimizer": "coap-adamw",
         "lr": 1e-3, "batch": 4, "seq": 16},
        cfg, pcfg, fault_injector=inj,
    )
    done = sup.run()

    assert done["step"] == 12
    assert float(done["loss"]) == pytest.approx(float(loss_base), rel=0.15)

    kinds = [e[0] for e in sup.events]
    assert kinds.count("spawn") == 3
    assert "sigkill" in kinds            # the injected preemption landed
    assert "notice" in kinds             # the injected warning landed
    assert kinds.count("crash") == 1     # SIGKILL -> heartbeat-declared crash
    assert kinds.count("drained") == 1   # notice -> clean drain
    crash = next(e for e in sup.events if e[0] == "crash")
    assert crash[2]["heartbeat"] in ("stale", "missing")

    # The workers' own journal: resume on 8 devices, then the migrated
    # resume on 4, then the zero-lost-steps resume after the drain.
    wev = read_events(cfg.events_path)
    resumes = [e for e in wev if e[0] == "resume"]
    assert len(resumes) == 3
    assert resumes[0][3] == 8 and resumes[1][3] == 4 and resumes[2][3] == 4
    # SIGKILL rolls back to a periodic checkpoint. The kill fires when the
    # HEARTBEAT shows step >= 7, so under scheduler lag the worker may have
    # already written the step-8 checkpoint — either periodic ckpt is a
    # legitimate reactive-resume point (unlike the drain below, which is
    # exact by protocol, not by timing).
    assert resumes[1][2] in (6, 8)
    assert any(e[0] == "migrate" for e in wev)
    drain_ev = next(e for e in wev if e[0] == "drain")
    drained = next(e for e in sup.events if e[0] == "drained")
    # Zero lost steps: the post-drain resume starts EXACTLY where the
    # drained worker stopped (ack step == drain step == resume step).
    assert resumes[2][2] == drain_ev[2] == drained[2]["step"]
    assert drain_ev[2] >= 9  # the notice arrived at/after its step
