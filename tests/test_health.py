"""Projection-health telemetry (``obs/health``): journal + monitor
mechanics, the analyze() verdict logic on injected pathologies, the
solver feedback loop, and the fleet_status health column.

The pathology tests run REAL optimizers with gradients constructed to
break the numerics — rank-1 floor on a high-rank gradient stream fires
RANK_STARVED, gradients past the int8 dynamic range fire
QUANT_SATURATED — so the verdicts are earned end-to-end, not asserted
against synthetic rows alone. EF_NOT_DRAINING / SUBSPACE_THRASH use
synthetic journals (their triggers are trajectory shapes, cheap to
write exactly)."""
import json
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core.api import OptimizerConfig, make_optimizer
from repro.obs import health
from repro.obs.registry import get_registry


@pytest.fixture(autouse=True)
def _clean_health():
    """Monitor and registry are process-wide singletons: put them back."""
    yield
    health.configure(None)
    get_registry().reset()


def _journal(tmp_path, name="health.jsonl"):
    return str(tmp_path / name)


def _write_rows(path, rows, torn_tail=False):
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
        if torn_tail:
            f.write('{"ts": 1.0, "bucket": "project:8x8:flo')


def _row(step, bucket, event, metrics):
    return {"ts": time.time(), "host": "t", "step": step,
            "bucket": bucket, "event": event, "metrics": metrics}


# ---------------------------------------------------------------------------
# label, journal reader, monitor
# ---------------------------------------------------------------------------
def test_bucket_label_is_rank_free():
    lab = health.bucket_label("project", (96, 64), "float32")
    assert lab == "project:96x64:float32"
    # Stable across rank changes by construction: the label has no rank
    # field, so a tightened plan still addresses the same journal bucket.
    assert "rank" not in lab
    assert health.bucket_label("conv", (48, 32, 3, 3), "float32") == (
        "conv:48x32x3x3:float32"
    )


def test_read_health_torn_tail_and_missing(tmp_path):
    path = _journal(tmp_path)
    good = [
        _row(0, "project:8x8:float32", "refresh", {"energy": 0.9}),
        _row(4, "project:8x8:float32", "refresh", {"energy": 0.8}),
    ]
    _write_rows(path, good, torn_tail=True)
    rows = health.read_health(path)
    assert len(rows) == 2
    assert [r["step"] for r in rows] == [0, 4]
    # Missing file and empty file are both just "no rows".
    assert health.read_health(str(tmp_path / "nope.jsonl")) == []
    empty = _journal(tmp_path, "empty.jsonl")
    open(empty, "w").close()
    assert health.read_health(empty) == []


def test_monitor_journals_and_mirrors_gauges(tmp_path):
    path = _journal(tmp_path)
    mon = health.configure(path, host="h9", sample_every=7)
    assert mon.enabled and mon.sample_every == 7
    mon.record(12, "project:8x8:float32", "refresh",
               {"energy": 0.75, "bad": "not-a-number"})
    rows = health.read_health(path)
    assert len(rows) == 1
    assert rows[0]["host"] == "h9"
    assert rows[0]["metrics"] == {"energy": 0.75}  # non-numeric dropped
    snap = get_registry().snapshot()
    assert snap["gauges"]["health/project:8x8:float32/energy"] == 0.75
    # Disabling restores the no-op monitor.
    health.configure(None)
    assert not health.get_monitor().enabled


# ---------------------------------------------------------------------------
# HealthReport codec: forward compat
# ---------------------------------------------------------------------------
def test_report_roundtrip_keeps_unknown_verdicts():
    d = {
        "codec": "coap-health/v2",
        "buckets": {"project:8x8:float32": {
            "verdicts": ["RANK_STARVED", "SOME_FUTURE_VERDICT"],
            "metrics": {"energy_median": 0.1},
        }},
        "verdicts": ["RANK_STARVED", "SOME_FUTURE_VERDICT"],
        "thresholds": {"energy_floor": 0.5},
    }
    rep = health.HealthReport.from_dict(d)
    assert not rep.ok()
    assert "SOME_FUTURE_VERDICT" in rep.verdicts  # preserved, not rejected
    back = rep.to_dict()
    assert back["codec"] == "coap-health/v2"
    assert back["verdicts"] == ["RANK_STARVED", "SOME_FUTURE_VERDICT"]
    with pytest.raises(ValueError):
        health.HealthReport.from_dict({"codec": "coap-plan/v1"})


def test_report_save_load(tmp_path):
    rep = health.analyze([_row(0, "b", "refresh", {"energy": 0.9})])
    path = str(tmp_path / "report.json")
    rep.save(path)
    back = health.HealthReport.load(path)
    assert back.codec == health.HEALTH_CODEC_V1
    assert back.buckets == rep.buckets
    assert back.ok()


def test_analyze_empty_and_malformed_rows():
    assert health.analyze([]).ok()
    rep = health.analyze([
        {"nonsense": 1}, "not-a-dict", {"bucket": 3, "metrics": {}},
        {"bucket": "b", "metrics": "nope"},
    ])
    assert rep.ok() and rep.buckets == {}


# ---------------------------------------------------------------------------
# Injected pathologies -> verdicts (real optimizer end-to-end)
# ---------------------------------------------------------------------------
def _run_steps(tx, params, steps, grad_fn):
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, params))
    for i in range(steps):
        _, state = step(grad_fn(i), state)
    jax.block_until_ready(jax.tree_util.tree_leaves(state))
    return state


def test_rank_starved_fires_on_rank1_high_rank_gradients(tmp_path):
    """Rank-1 projection of a full-rank random gradient stream captures
    ~1/64 of the energy -> RANK_STARVED after warmup."""
    path = _journal(tmp_path)
    health.configure(path, host="t")
    params = {"w": jnp.zeros((4, 96, 64))}
    tx = make_optimizer(OptimizerConfig(
        name="coap-adamw", learning_rate=1e-3, rank=1, t_update=2, lam=2,
        min_dim=32, stacked_state=True, grad_clip=None,
    ))

    def grad_fn(i):
        key = jax.random.key(100 + i)
        return {"w": jax.random.normal(key, (4, 96, 64))}

    _run_steps(tx, params, 10, grad_fn)
    rep = health.analyze_journal(path)
    # Label carries the leaf's own stacked shape (4 layers of 96x64).
    label = "project:4x96x64:float32"
    assert label in rep.buckets
    b = rep.buckets[label]
    assert b["n_refresh"] >= 4  # t_update=2 -> refreshes at 0,2,4,...
    assert b["metrics"]["energy_median"] < 0.5
    assert health.VERDICT_RANK_STARVED in b["verdicts"]
    assert health.VERDICT_RANK_STARVED in rep.verdicts


def test_healthy_rank_stays_verdict_free(tmp_path):
    """A rank-1 gradient stream under a rank-32 floor: energy ~= 1,
    overlap high, no verdict."""
    path = _journal(tmp_path)
    health.configure(path, host="t")
    params = {"w": jnp.zeros((4, 96, 64))}
    tx = make_optimizer(OptimizerConfig(
        name="coap-adamw", learning_rate=1e-3, rank=32, t_update=2, lam=2,
        min_dim=32, stacked_state=True, grad_clip=None,
    ))
    _run_steps(tx, params, 10,
               lambda i: {"w": 0.1 * jnp.ones((4, 96, 64))})
    rep = health.analyze_journal(path)
    b = rep.buckets["project:4x96x64:float32"]
    assert b["metrics"]["energy_median"] > 0.9
    assert b["verdicts"] == []
    assert rep.ok()


def test_quant_saturated_fires_past_int8_range(tmp_path):
    """Gradients at 1e25 push the second moment past fp32 -> non-finite
    block scales -> QUANT_SATURATED from the sampled codec stats."""
    path = _journal(tmp_path)
    health.configure(path, host="t")
    params = {"w": jnp.zeros((4, 96, 64))}
    tx = make_optimizer(OptimizerConfig(
        name="8bit-coap-adamw", learning_rate=1e-3, rank=8, t_update=4,
        lam=2, min_dim=32, stacked_state=True, grad_clip=None,
    ))
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, params))
    g = {"w": 1e25 * jnp.ones((4, 96, 64))}
    for i in range(6):
        _, state = step(g, state)
        health.observe_state(state, i)
    rep = health.analyze_journal(path)
    sats = [b for b in rep.buckets.values()
            if health.VERDICT_QUANT_SATURATED in b["verdicts"]]
    assert sats, f"no QUANT_SATURATED in {rep.to_dict()}"
    assert any(b["metrics"].get("scale_nonfinite_max", 0) > 0
               or b["metrics"].get("sat_rate_max", 0) > 0.05
               for b in sats)


def test_quantized_healthy_run_no_quant_verdict(tmp_path):
    """Sane gradient scale: excess-rail saturation stays ~0 (the one
    guaranteed absmax rail per block is baseline-corrected away)."""
    path = _journal(tmp_path)
    health.configure(path, host="t")
    params = {"w": jnp.zeros((4, 96, 64))}
    tx = make_optimizer(OptimizerConfig(
        name="8bit-coap-adamw", learning_rate=1e-3, rank=8, t_update=4,
        lam=2, min_dim=32, stacked_state=True, grad_clip=None,
    ))
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, params))
    for i in range(6):
        g = {"w": 0.1 * jax.random.normal(jax.random.key(i), (4, 96, 64))}
        _, state = step(g, state)
        health.observe_state(state, i)
    rep = health.analyze_journal(path)
    assert health.VERDICT_QUANT_SATURATED not in rep.verdicts
    samples = [b for b in rep.buckets.values() if b["n_sample"] > 0]
    assert samples
    for b in samples:
        assert b["metrics"]["sat_rate_max"] <= 0.05


def test_observe_state_reads_no_gradient(tmp_path):
    """observe_state's signature takes only (opt_state, step): the
    zero-extra-G-round-trips property is structural, and a disabled
    monitor short-circuits to 0 rows."""
    assert health.observe_state({"not": "a state"}, 0) == 0
    health.configure(_journal(tmp_path))
    assert health.observe_state((), 5) == 0  # no projected states found


def test_ef_not_draining_on_growing_sidecar():
    """Linearly growing ef_rms (last-third/first-third > 3x) fires;
    a bounded sidecar does not."""
    bucket = "project:96x64:float32"
    growing = [
        _row(i, bucket, "sample", {"ef_rms": float(1 + i)})
        for i in range(9)
    ]
    rep = health.analyze(growing)
    b = rep.buckets[bucket]
    assert b["metrics"]["ef_growth_ratio"] > 3.0
    assert health.VERDICT_EF_NOT_DRAINING in b["verdicts"]

    bounded = [
        _row(i, bucket, "sample", {"ef_rms": 1.0 + 0.01 * (i % 2)})
        for i in range(9)
    ]
    assert health.analyze(bounded).ok()
    # Below the minimum sample count there is no judgment either way.
    few = growing[: int(health.DEFAULT_THRESHOLDS["ef_min_samples"]) - 1]
    assert health.analyze(few).ok()


def test_subspace_thrash_on_low_overlap_after_warmup():
    bucket = "project:96x64:float32"
    rows = []
    for i, ov in enumerate([0.9, 0.8, 0.1, 0.15, 0.05, 0.1]):
        rows.append(_row(2 * i, bucket, "refresh",
                         {"energy": 0.9, "subspace_overlap": ov}))
    rep = health.analyze(rows)
    b = rep.buckets[bucket]
    # Warmup refreshes (the first 2, incl. the init from-nothing one) are
    # excluded from the overlap judgment.
    assert b["metrics"]["overlap_median"] < 0.5
    assert b["verdicts"] == [health.VERDICT_SUBSPACE_THRASH]

    stable = [
        _row(2 * i, bucket, "refresh",
             {"energy": 0.9, "subspace_overlap": ov})
        for i, ov in enumerate([0.2, 0.3, 0.9, 0.95, 0.9, 0.92])
    ]
    assert health.analyze(stable).ok()


def test_conv_bucket_emits_refresh_rows(tmp_path):
    """Tucker-2 conv buckets journal refresh health too."""
    path = _journal(tmp_path)
    health.configure(path, host="t")
    params = {"conv": {"kernel": jnp.zeros((48, 32, 3, 3))}}
    tx = make_optimizer(OptimizerConfig(
        name="coap-adamw", learning_rate=1e-3, rank=8, t_update=2, lam=2,
        min_dim=16, stacked_state=True, grad_clip=None,
    ))

    def grad_fn(i):
        return {"conv": {"kernel": 0.1 * jax.random.normal(
            jax.random.key(i), (48, 32, 3, 3))}}

    _run_steps(tx, params, 6, grad_fn)
    rows = health.read_health(path)
    conv_rows = [r for r in rows if r["bucket"].startswith("conv:")]
    assert conv_rows, f"no conv rows in {[r['bucket'] for r in rows]}"
    for r in conv_rows:
        assert 0.0 <= r["metrics"]["energy"] <= 1.0 + 1e-5
        assert "subspace_overlap" in r["metrics"]


# ---------------------------------------------------------------------------
# Solver feedback loop
# ---------------------------------------------------------------------------
_TREE = {
    "blk0": {"w": jnp.zeros((96, 64)), "norm": jnp.zeros((64,))},
    "tower": {"conv0": {"kernel": jnp.zeros((48, 32, 3, 3))}},
}
_SOLVE_KW = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)


def _solve(**kw):
    from repro.plan.solver import solve

    return solve(_TREE, None, **_SOLVE_KW, **kw)


def _proj_bucket(plan):
    return next(b for b in plan.buckets if b.kind == "project")


def _conv_bucket(plan):
    return next(b for b in plan.buckets if b.kind == "conv")


def _report_for(plan, verdicts_by_kind, metrics_by_kind=None):
    buckets = {}
    for b in plan.buckets:
        if b.kind not in verdicts_by_kind:
            continue
        label = health.bucket_label(b.kind, b.shape, b.dtype)
        buckets[label] = {
            "verdicts": list(verdicts_by_kind[b.kind]),
            "metrics": dict((metrics_by_kind or {}).get(b.kind, {})),
        }
    return health.HealthReport(
        buckets=buckets, verdicts=sorted(
            {v for vs in verdicts_by_kind.values() for v in vs}
        ),
        thresholds=dict(health.DEFAULT_THRESHOLDS),
    )


def test_solve_health_none_bit_identical():
    blind = _solve()
    none = _solve(health_report=None)
    assert json.dumps(blind.to_dict(), sort_keys=True) == json.dumps(
        none.to_dict(), sort_keys=True
    )
    assert "health_adjustments" not in blind.cost


def test_solve_empty_report_changes_nothing_but_records_consult():
    blind = _solve()
    rep = health.HealthReport(
        buckets={}, verdicts=[],
        thresholds=dict(health.DEFAULT_THRESHOLDS),
    )
    plan = _solve(health_report=rep.to_dict())
    assert plan.cost["health_adjustments"] == []
    assert [b.spec for b in plan.buckets] == [b.spec for b in blind.buckets]


def test_solve_tightens_on_rank_starved_and_thrash():
    blind = _solve()
    for verdict in (health.VERDICT_RANK_STARVED,
                    health.VERDICT_SUBSPACE_THRASH):
        rep = _report_for(blind, {"project": [verdict], "conv": [verdict]})
        plan = _solve(health_report=rep)  # object form, not dict
        pb, pb0 = _proj_bucket(plan), _proj_bucket(blind)
        assert pb.spec.rank > pb0.spec.rank
        cb, cb0 = _conv_bucket(plan), _conv_bucket(blind)
        assert cb.spec.rank_o > cb0.spec.rank_o
        assert cb.spec.rank_i > cb0.spec.rank_i
        adjusts = plan.cost["health_adjustments"]
        assert {a["action"] for a in adjusts} == {"tighten"}
        assert len(adjusts) == 2


def test_solve_relaxes_on_energy_headroom():
    blind = _solve()
    rep = _report_for(
        blind, {"project": []},
        metrics_by_kind={"project": {"energy_median": 0.99}},
    )
    plan = _solve(health_report=rep.to_dict())
    pb, pb0 = _proj_bucket(plan), _proj_bucket(blind)
    assert pb.spec.rank < pb0.spec.rank
    assert pb.spec.rank >= 1
    adjusts = plan.cost["health_adjustments"]
    assert len(adjusts) == 1 and adjusts[0]["action"] == "relax"
    # A verdicted bucket never relaxes, however high its energy.
    rep2 = _report_for(
        blind, {"project": [health.VERDICT_QUANT_SATURATED]},
        metrics_by_kind={"project": {"energy_median": 0.99}},
    )
    plan2 = _solve(health_report=rep2.to_dict())
    assert _proj_bucket(plan2).spec.rank == pb0.spec.rank


def test_solve_ignores_unknown_verdicts():
    """Forward compat: a newer writer's verdict neither tightens nor
    blocks anything it should not."""
    blind = _solve()
    rep = _report_for(blind, {"project": ["SOME_FUTURE_VERDICT"]})
    plan = _solve(health_report=rep.to_dict())
    assert _proj_bucket(plan).spec.rank == _proj_bucket(blind).spec.rank
    assert plan.cost["health_adjustments"] == []


# ---------------------------------------------------------------------------
# fleet_status health column
# ---------------------------------------------------------------------------
def test_fleet_status_health_column(tmp_path):
    from repro.launch import fleet_status as fs

    now = time.time()
    sick = tmp_path / "sick"
    sick.mkdir()
    (sick / "heartbeat.json").write_text(json.dumps(
        {"time": now, "host": "sick", "phase": "train", "step": 40}
    ))
    rows = [
        _row(2 * i, "project:96x64:float32", "refresh",
             {"energy": 0.05, "subspace_overlap": 0.9})
        for i in range(5)
    ]
    _write_rows(str(sick / "health.jsonl"), rows, torn_tail=True)

    quiet = tmp_path / "quiet"
    quiet.mkdir()
    (quiet / "heartbeat.json").write_text(json.dumps(
        {"time": now, "host": "quiet", "phase": "train", "step": 40}
    ))

    doc = fs.collect([str(sick), str(quiet)], None)
    by_host = {h["host"]: h for h in doc["hosts"]}
    assert by_host["sick"]["health"]["ok"] is False
    assert by_host["sick"]["health"]["verdicts"] == ["RANK_STARVED"]
    assert by_host["sick"]["health"]["n_buckets"] == 1
    assert by_host["quiet"]["health"] is None  # no journal -> no column

    table = fs.render(doc)
    assert "RANK_STARVED" in table
    assert "| health |" in table.splitlines()[0]
