"""Eqn 6 (correlation-aware P update): closed-form grads vs autodiff, descent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import correlation

jax.config.update("jax_platform_name", "cpu")


def _rand(m, n, r, seed=0):
    key = jax.random.key(seed)
    g = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    p = jax.random.normal(jax.random.fold_in(key, 2), (n, r)) / np.sqrt(r)
    mp = 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (m, r))
    return g, p, mp


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(8, 64),
    n=st.integers(8, 48),
    r=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_closed_form_grad_matches_autodiff(m, n, r, seed):
    r = min(r, n - 1, m - 1)
    g, p, mp = _rand(m, n, max(r, 1), seed)
    val, grad = correlation.loss_and_grad(p, g, mp)
    auto_val = correlation.objective(p, g, mp)
    auto_grad = jax.grad(lambda q: correlation.objective(q, g, mp).sum())(p)
    np.testing.assert_allclose(val, auto_val, rtol=1e-5)
    np.testing.assert_allclose(grad, auto_grad, rtol=5e-4, atol=5e-5)


def test_batched_matches_loop():
    gs, ps, mps = [], [], []
    for s in range(3):
        g, p, mp = _rand(32, 24, 6, seed=s)
        gs.append(g), ps.append(p), mps.append(mp)
    gb, pb, mb = jnp.stack(gs), jnp.stack(ps), jnp.stack(mps)
    vb, gradb = correlation.loss_and_grad(pb, gb, mb)
    for i in range(3):
        v, gr = correlation.loss_and_grad(ps[i], gs[i], mps[i])
        np.testing.assert_allclose(vb[i], v, rtol=1e-6)
        np.testing.assert_allclose(gradb[i], gr, rtol=1e-5, atol=1e-7)


def test_sgd_update_descends_objective():
    g, p, mp = _rand(64, 48, 8, seed=7)
    before = correlation.objective(p, g, mp)
    p1 = correlation.sgd_update(p, g, mp, lr=0.05, steps=1)
    after1 = correlation.objective(p1, g, mp)
    p5 = correlation.sgd_update(p, g, mp, lr=0.05, steps=5)
    after5 = correlation.objective(p5, g, mp)
    assert float(after1) < float(before)
    assert float(after5) <= float(after1) + 1e-6


def test_direction_term_increases_cosine():
    """Descent on Eqn 6 must INCREASE CosSim(M̂, G) when MSE is held
    constant — this is the sign the paper's appendix Eqn 3 typo would get
    wrong (see module docstring in core/correlation.py).

    Two checks, both isolating the direction term (the full gradient may
    trade a little cosine for MSE when the two terms conflict, as they
    mildly do at this seed):
      1. a step along the direction-term component alone (MSE factor
         frozen) raises the cosine;
      2. over a full SGD trajectory, the product-rule sign keeps the
         cosine strictly higher than the typo'd ``+`` combination would.
    """
    g, p, mp = _rand(64, 48, 8, seed=11)
    # Make the moment correlated with g so the cosine term is informative.
    mp = jnp.einsum("mn,nr->mr", g, p) + 0.05 * mp

    def cos_of(pp):
        return float(
            correlation.cos_sim_rows(jnp.einsum("mr,nr->mn", mp, pp), g)
        )

    cos_before = cos_of(p)

    # (1) direction term alone: descend -(−MSE·∇Cos), MSE factor frozen.
    g_cos, _ = correlation.cos_grad(p, g, mp)
    _, v_mse = correlation.mse_grad(p, g)
    p_dir = p - 0.1 * (-float(v_mse) * g_cos)
    assert cos_of(p_dir) > cos_before

    # (2) full trajectory: product-rule sign vs the appendix-typo sign.
    def sgd(sign, steps=10, lr=0.1):
        pc = p
        for _ in range(steps):
            g_mse, _ = correlation.mse_grad(pc, g)
            g_c, v_c = correlation.cos_grad(pc, g, mp)
            _, v_m = correlation.mse_grad(pc, g)
            pc = pc - lr * (g_mse * (1.0 - v_c) + sign * g_c * v_m)
        return pc

    p_ours = correlation.sgd_update(p, g, mp, lr=0.1, steps=10)
    p_typo = sgd(+1.0)
    obj_after = correlation.objective(p_ours, g, mp)
    obj_before = correlation.objective(p, g, mp)
    assert float(obj_after) < float(obj_before)
    assert cos_of(p_ours) > cos_of(p_typo) + 1e-3


def test_objective_zero_when_p_orthonormal_full_rank():
    """With r == n and orthonormal P, reconstruction is exact ⇒ MSE term 0."""
    n = 16
    g = jax.random.normal(jax.random.key(0), (32, n))
    p = jnp.eye(n)
    mp = jnp.einsum("mn,nr->mr", g, p)
    obj = correlation.objective(p, g, mp)
    np.testing.assert_allclose(obj, 0.0, atol=1e-9)
