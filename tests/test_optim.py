"""Optimizer substrate: Adam closed form, Adafactor factoring, schedules,
clipping, chaining — plus hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim


def test_adam_single_step_closed_form():
    params = {"w": jnp.zeros((4,))}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5, 0.0])}
    tx = optim.adam(learning_rate=0.1, b1=0.9, b2=0.999, eps=1e-8)
    state = tx.init(params)
    upd, _ = tx.update(g, state, params)
    # step 1: m̂ = g, v̂ = g², update = -lr·g/(|g|+eps)
    expected = -0.1 * np.sign([1.0, -2.0, 0.5, 0.0]) * (
        np.abs([1.0, -2.0, 0.5, 0.0]) > 0
    )
    got = np.asarray(upd["w"])
    np.testing.assert_allclose(got[:3], expected[:3], rtol=1e-4)
    assert got[3] == 0.0


def test_adamw_decoupled_weight_decay():
    params = {"w": jnp.ones((2,))}
    g = {"w": jnp.zeros((2,))}
    tx = optim.adamw(learning_rate=0.1, weight_decay=0.5)
    upd, _ = tx.update(g, tx.init(params), params)
    # zero grad ⇒ update = -lr·wd·w = -0.05
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.05, rtol=1e-5)


def test_adafactor_factored_state_small():
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((64,))}
    tx = optim.adafactor(learning_rate=0.01)
    state = tx.init(params)
    leaves = jax.tree_util.tree_leaves(state)
    total = sum(x.size for x in leaves)
    # factored: 64+32 for w, 64 unfactored for b, + scalars/placeholders
    assert total < 64 * 32 / 4, total
    g = jax.tree_util.tree_map(lambda p: 0.1 * jnp.ones_like(p), params)
    upd, state = tx.update(g, state, params)
    for u in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(u)))


@settings(max_examples=10, deadline=None)
@given(norm=st.floats(0.1, 100.0), max_norm=st.floats(0.5, 5.0))
def test_clip_by_global_norm_invariant(norm, max_norm):
    g = {"a": jnp.asarray([norm, 0.0]), "b": jnp.zeros((3,))}
    tx = optim.clip_by_global_norm(max_norm)
    upd, _ = tx.update(g, tx.init(g), None)
    out_norm = float(jnp.sqrt(sum(jnp.sum(x**2)
                                  for x in jax.tree_util.tree_leaves(upd))))
    assert out_norm <= max_norm * 1.001
    if norm <= max_norm:
        np.testing.assert_allclose(out_norm, norm, rtol=1e-5)


def test_warmup_cosine_schedule_shape():
    s = optim.warmup_cosine_schedule(1.0, warmup_steps=10, decay_steps=100)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.asarray(55))) < 1.0
    np.testing.assert_allclose(float(s(jnp.asarray(100))), 0.0, atol=1e-6)


def test_chain_order_matters():
    params = {"w": jnp.ones((2,))}
    g = {"w": jnp.asarray([10.0, 0.0])}
    # clip-then-scale != scale-then-clip
    a = optim.chain(optim.clip_by_global_norm(1.0), optim.scale(2.0))
    b = optim.chain(optim.scale(2.0), optim.clip_by_global_norm(1.0))
    ua, _ = a.update(g, a.init(params), params)
    ub, _ = b.update(g, b.init(params), params)
    assert float(jnp.linalg.norm(ua["w"])) == pytest.approx(2.0, rel=1e-4)
    assert float(jnp.linalg.norm(ub["w"])) == pytest.approx(1.0, rel=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), steps=st.integers(2, 10))
def test_adam_is_scale_free_in_gradient(seed, steps):
    """Adam invariant: scaling all gradients by c>0 leaves updates unchanged
    (after enough steps for eps to be negligible)."""
    key = jax.random.key(seed)
    params = {"w": jnp.zeros((8, 8))}
    tx = optim.adam(learning_rate=0.1, eps=1e-12)
    s1, s2 = tx.init(params), tx.init(params)
    u1 = u2 = None
    for i in range(steps):
        g = jax.random.normal(jax.random.fold_in(key, i), (8, 8))
        u1, s1 = tx.update({"w": g}, s1, params)
        u2, s2 = tx.update({"w": 100.0 * g}, s2, params)
    np.testing.assert_allclose(u1["w"], u2["w"], rtol=1e-3, atol=1e-6)
