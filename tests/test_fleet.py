"""Fleet plan consensus (train/fleet.py): liveness election, staged
proposals, deterministic tie-break, first-wins commit — two supervisors
racing a replan must converge on ONE identical coap-plan/v1 artifact."""
import json
import os

from repro.train import fleet


def _cc(tmp_path, host, **kw):
    return fleet.PlanConsensus(
        fleet.FleetConfig(
            fleet_dir=str(tmp_path), host_id=host,
            adopt_timeout_s=kw.pop("adopt_timeout_s", 0.5),
            poll_interval_s=0.01, **kw,
        )
    )


def test_plan_digest_is_order_insensitive():
    a = {"x": 1, "y": [1, 2], "z": {"b": 2, "a": 1}}
    b = {"z": {"a": 1, "b": 2}, "y": [1, 2], "x": 1}
    assert fleet.plan_digest(a) == fleet.plan_digest(b)
    assert fleet.plan_digest(a) != fleet.plan_digest({"x": 2})


def test_leader_is_min_alive_host(tmp_path):
    a = _cc(tmp_path, "host-a")
    b = _cc(tmp_path, "host-b")
    a.beat()
    b.beat()
    assert a.leader() == b.leader() == "host-a"
    # host-a's lease lapses -> host-b takes over deterministically.
    now = [1000.0]
    d2 = str(tmp_path / "lapse")
    mk = lambda host: fleet.PlanConsensus(  # noqa: E731
        fleet.FleetConfig(fleet_dir=d2, host_id=host, member_timeout_s=30.0),
        time_fn=lambda: now[0],
    )
    a2, b2 = mk("host-a"), mk("host-b")
    a2.beat()
    b2.beat()
    assert b2.leader() == "host-a"
    now[0] += 100.0  # a never beats again; b re-leases
    b2.beat()
    assert b2.alive_hosts() == ["host-b"]
    assert b2.leader() == "host-b"


def test_commit_tie_break_is_order_independent(tmp_path):
    """Two hosts stage DIFFERENT proposals; whoever commits first, the
    committed value is the tie-break winner (min by digest, host) — both
    interleavings land the identical artifact."""
    plan_a = {"version": "coap-plan/v1", "knob": 1}
    plan_b = {"version": "coap-plan/v1", "knob": 2}
    winner_digest = min(fleet.plan_digest(plan_a), fleet.plan_digest(plan_b))

    committed = []
    for order in [("a-first", True), ("b-first", False)]:
        epoch, a_commits_first = order
        a = _cc(tmp_path, "host-a")
        b = _cc(tmp_path, "host-b")
        a.stage(epoch, plan_a)
        b.stage(epoch, plan_b)
        first, second = (a, b) if a_commits_first else (b, a)
        r1 = first.commit(epoch)
        r2 = second.commit(epoch)
        assert r1 == r2  # second commit adopts the landed artifact
        assert r1["digest"] == winner_digest
        committed.append(r1)
    assert committed[0] == committed[1]


def test_commit_requires_a_staged_proposal(tmp_path):
    c = _cc(tmp_path, "host-a")
    try:
        c.commit("e0")
        raise AssertionError("commit without proposals should raise")
    except ValueError:
        pass


def test_committed_artifact_is_never_torn(tmp_path):
    """The commit file appears atomically with complete content (hardlink
    of a fully-written temp file): whatever committed() returns parses."""
    c = _cc(tmp_path, "host-a")
    c.stage("e1", {"version": "coap-plan/v1", "big": list(range(1000))})
    rec = c.commit("e1")
    path = os.path.join(str(tmp_path), "epochs", "e1", "plan.json")
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk == rec
    assert not [p for p in os.listdir(os.path.dirname(path))
                if p.endswith(".tmp")]


def test_plan_for_epoch_one_solver_rest_adopt(tmp_path):
    """The elected leader solves + publishes; a peer adopts the committed
    plan WITHOUT invoking its own solver."""
    a = _cc(tmp_path, "host-a")
    b = _cc(tmp_path, "host-b")
    a.beat()
    b.beat()
    solves = {"a": 0, "b": 0}

    def solve_a():
        solves["a"] += 1
        return {"version": "coap-plan/v1", "by": "a"}

    def solve_b():
        solves["b"] += 1
        return {"version": "coap-plan/v1", "by": "b"}

    plan1, role1 = a.plan_for_epoch("0:8xN", solve_a)
    plan2, role2 = b.plan_for_epoch("0:8xN", solve_b)
    assert (role1, role2) == ("published", "adopted")
    assert plan1 == plan2 == {"version": "coap-plan/v1", "by": "a"}
    assert solves == {"a": 1, "b": 0}


def test_plan_for_epoch_peer_takes_over_dead_leader(tmp_path):
    """The leader dies before committing: the peer's adopt wait times out
    and it solves + commits itself — liveness without extra rounds."""
    now = [0.0]
    b = fleet.PlanConsensus(
        fleet.FleetConfig(fleet_dir=str(tmp_path), host_id="host-b",
                          member_timeout_s=5.0, adopt_timeout_s=1.0,
                          poll_interval_s=0.01),
        time_fn=lambda: now[0],
        sleep_fn=lambda s: now.__setitem__(0, now[0] + max(s, 0.01)),
    )
    # host-a beat once (so b is not leader) and then died.
    a = fleet.PlanConsensus(
        fleet.FleetConfig(fleet_dir=str(tmp_path), host_id="host-a"),
        time_fn=lambda: now[0],
    )
    a.beat()
    now[0] += 10.0  # a's lease lapses during b's wait
    plan, role = b.plan_for_epoch(
        "60:4xN", lambda: {"version": "coap-plan/v1", "by": "b"}
    )
    assert role == "published"
    assert plan["by"] == "b"
