"""Flash-attention Pallas kernel vs naive oracle (interpret mode): values,
gradients, GQA grouping, windows, softcap, ragged shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attend_flash
from repro.models.attention import _attend, _causal_mask


def _qkv(b, t, s, h, kh, hd, seed=0, dtype=jnp.float32):
    key = jax.random.key(seed)
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, kh, hd), dtype)
    return q, k, v


@settings(max_examples=6, deadline=None)
@given(
    t=st.integers(64, 300),
    h=st.sampled_from([4, 8]),
    kh=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([None, 50]),
    seed=st.integers(0, 20),
)
def test_flash_matches_naive(t, h, kh, window, seed):
    hd = 32
    q, k, v = _qkv(2, t, t, h, kh, hd, seed)
    scale = 1.0 / np.sqrt(hd)
    ref = _attend(q, k, v, _causal_mask(t, t, 0, window), None, scale)
    got = attend_flash(q, k, v, scale=scale, window=window, interpret=True,
                       qb=64, kb=64)
    np.testing.assert_allclose(got, ref, atol=5e-5, rtol=1e-4)


def test_flash_softcap_and_grads():
    t, h, kh, hd = 128, 4, 2, 32
    q, k, v = _qkv(1, t, t, h, kh, hd, 3)
    scale = 1.0 / np.sqrt(hd)

    def loss_ref(q, k, v):
        o = _attend(q, k, v, _causal_mask(t, t, 0, None), 20.0, scale)
        return jnp.sum(o * o)

    def loss_flash(q, k, v):
        o = attend_flash(q, k, v, scale=scale, softcap=20.0, interpret=True,
                         qb=64, kb=64)
        return jnp.sum(o * o)

    np.testing.assert_allclose(loss_flash(q, k, v), loss_ref(q, k, v),
                               rtol=1e-5)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, n in zip(gr, gf, "qkv"):
        np.testing.assert_allclose(b, a, atol=1e-3, rtol=1e-3, err_msg=n)


def test_flash_bf16_inputs():
    t, h, kh, hd = 128, 4, 4, 64
    q, k, v = _qkv(2, t, t, h, kh, hd, 5, jnp.bfloat16)
    scale = 1.0 / np.sqrt(hd)
    ref = _attend(q, k, v, _causal_mask(t, t, 0, None), None, scale)
    got = attend_flash(q, k, v, scale=scale, interpret=True, qb=64, kb=64)
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), atol=3e-2, rtol=3e-2
    )
