"""Stacked optimizer-state subsystem: codec, A/B parity, consumer contracts.

Covers the tentpole guarantees:
  * codec round-trip: ``decode(encode(x)) == x`` bit-for-bit (int8 codes
    included) and ``leaf_view`` matches ``decode``;
  * stacked vs per-leaf execution parity for every strategy, quantized and
    fp32, bf16 gradient streaming and flora RNG — the same standard as the
    existing ``bucket_leaves`` A/B guarantee (int8 states bit-exact —
    quantized runs are bit-exact throughout — floats to XLA-fusion ulp);
  * checkpoint cross-mode restore: a checkpoint saved in stacked mode
    restores into a per-leaf template and vice versa, exactly;
  * accounting: identical byte tables for both layouts;
  * cross-pod compression addresses stacked state through ``leaf_view``
    and matches per-leaf state compression bitwise;
  * benchmark gate: the per-step stack/scatter state traffic removed on
    the LLaMA-1B bucket structure is >=2x (BENCH_state methodology).
"""
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stacked_state as ss
from repro.core.accounting import optimizer_state_bytes
from repro.core.coap_adam import (
    ProjectedAdamConfig,
    ProjLeaf,
    scale_by_projected_adam,
)
from repro.core.coap_adafactor import (
    ProjectedAdafactorConfig,
    scale_by_projected_adafactor,
)
from repro.core.projector import ProjectionRules
from repro.train import checkpoint as ckpt

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)


def _cfg(**kw):
    kw.setdefault("rules", ProjectionRules(rank=16, min_dim=8))
    return ProjectedAdamConfig(**kw)


def _params():
    """Two projected buckets + odd projected + conv bucket + dense leaves."""
    p = {f"a{i}": {"w": jnp.zeros((96, 64))} for i in range(4)}
    p.update({f"b{i}": {"w": jnp.zeros((128, 48))} for i in range(2)})
    p["c0"] = {"w": jnp.zeros((80, 72))}
    p["conv_k"] = 0.01 * jnp.ones((128, 128, 3, 3))
    p["bias"] = jnp.zeros((7,))
    p["tiny"] = jnp.zeros((4, 4))
    return p


def _grads(params, seed=0):
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )


def _run(cfg, params, g, steps=3):
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    step = jax.jit(lambda gg, s: tx.update(gg, s, None))
    for _ in range(steps):
        upd, state = step(g, state)
    return tx, upd, state


def _as_perleaf_tree(state_leaves, treedef):
    if isinstance(state_leaves, ss.StackedLeaves):
        return jax.tree_util.tree_unflatten(treedef, ss.decode(state_leaves))
    return state_leaves


# ---------------------------------------------------------------------------
# codec unit behaviour
# ---------------------------------------------------------------------------
def test_encode_decode_roundtrip_bitexact():
    params = _params()
    cfg = _cfg(quantize=True, stacked_state=False)
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(
        _grads(params), state
    )
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    layout = ss.build_layout(
        cfg.rules.spec_for,
        [ss.path_str(kp) for kp, _ in flat],
        [leaf.shape for _, leaf in flat],
        [jnp.dtype(leaf.dtype).name for _, leaf in flat],
    )
    flat_states = treedef.flatten_up_to(state.leaves)
    stacked = ss.encode(layout, flat_states)
    decoded = ss.decode(stacked)
    assert len(decoded) == len(flat_states)
    for a, b in zip(
        jax.tree_util.tree_leaves(flat_states),
        jax.tree_util.tree_leaves(decoded),
    ):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # leaf_view agrees with decode at every position
    for i in range(layout.n_leaves):
        for a, b in zip(
            jax.tree_util.tree_leaves(ss.leaf_view(stacked, i)),
            jax.tree_util.tree_leaves(decoded[i]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_deterministic_and_conv_buckets():
    params = _params()
    cfg = _cfg()
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    mk = lambda: ss.build_layout(
        cfg.rules.spec_for,
        [ss.path_str(kp) for kp, _ in flat],
        [leaf.shape for _, leaf in flat],
        [jnp.dtype(leaf.dtype).name for _, leaf in flat],
    )
    la, lb = mk(), mk()
    assert la == lb  # pure function of the tree
    assert la.signature() == lb.signature()
    # stacked-bucket/v2: the conv leaf BUCKETS (no residual tail) and
    # joins the staggerable buckets after the projected ones
    assert la.tail == ()
    conv = [b for b in la.buckets if b.kind == ss.BUCKET_CONV]
    assert [b.paths for b in conv] == [("conv_k",)]
    assert la.staggerable_bucket_sizes() == la.proj_bucket_sizes() + [1]
    # projected buckets come first, with the multi-leaf buckets intact
    proj = [b for b in la.buckets if b.kind == ss.BUCKET_PROJECT]
    assert [len(b.indices) for b in proj] == [4, 2, 1]
    assert [b.kind for b in la.buckets].index(ss.BUCKET_CONV) == len(proj)
    # the legacy classification still reproduces the v1 conv-in-tail layout
    lv1 = ss.build_layout(
        cfg.rules.spec_for,
        [ss.path_str(kp) for kp, _ in flat],
        [leaf.shape for _, leaf in flat],
        [jnp.dtype(leaf.dtype).name for _, leaf in flat],
        classify=ss.classify_v1,
    )
    assert [t.path for t in lv1.tail] == ["conv_k"]
    assert not [b for b in lv1.buckets if b.kind == ss.BUCKET_CONV]
    # every index appears exactly once across buckets + tail
    seen = sorted(
        i for b in la.buckets for i in b.indices
    ) + sorted(t.index for t in la.tail)
    assert sorted(seen) == list(range(la.n_leaves))


def test_stacked_requires_bucketing():
    with pytest.raises(ValueError, match="bucket_leaves"):
        _cfg(stacked_state=True, bucket_leaves=False)


def test_stacked_state_rejects_mismatched_tree():
    params = _params()
    tx = scale_by_projected_adam(_cfg(stacked_state=True))
    state = tx.init(params)
    other = {"x": jnp.zeros((96, 64)), "y": jnp.zeros((96, 64))}
    with pytest.raises(ValueError, match="stacked optimizer state"):
        tx.update(_grads(other), state, None)


# ---------------------------------------------------------------------------
# execution parity: stacked vs per-leaf storage
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("strategy", ["coap", "galore", "flora"])
def test_stacked_matches_per_leaf(quantize, strategy):
    """Same updates and states from both storage modes: int8 (and entire
    quantized runs) bit-exact, floats to XLA-fusion ulp — the established
    bucket_leaves A/B standard, now extended to the state layout."""
    params = _params()
    g = _grads(params, seed=3)
    treedef = jax.tree_util.tree_structure(params)
    outs = {}
    for stacked in (True, False):
        _, upd, state = _run(
            _cfg(strategy=strategy, quantize=quantize, t_update=2, lam=2,
                 stacked_state=stacked),
            params, g,
        )
        outs[stacked] = (upd, _as_perleaf_tree(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8 or quantize:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


def test_stacked_bf16_gradient_streaming_parity():
    """bf16 grads through stacked storage: state bits match the fp32-fed
    stacked run (upcasting bf16 is exact), as in the per-leaf guarantee."""
    params = _params()
    g16 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16), _grads(params, seed=5)
    )
    g32 = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g16)
    treedef = jax.tree_util.tree_structure(params)
    out = {}
    for name, g in [("fp32", g32), ("bf16", g16)]:
        _, upd, state = _run(
            _cfg(t_update=2, lam=2, quantize=True, stacked_state=True),
            params, g,
        )
        out[name] = (upd, _as_perleaf_tree(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(out["fp32"][1]),
                    jax.tree_util.tree_leaves(out["bf16"][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stacked_adafactor_matches_per_leaf_bitwise():
    """The adafactor variant computes per-leaf through leaf_view slices, so
    stacked and per-leaf modes are bit-identical there."""
    params = _params()
    g = _grads(params, seed=7)
    treedef = jax.tree_util.tree_structure(params)
    outs = {}
    for stacked in (True, False):
        cfg = ProjectedAdafactorConfig(
            rules=ProjectionRules(rank=16, min_dim=8), t_update=2, lam=2,
            stacked_state=stacked,
        )
        tx = scale_by_projected_adafactor(cfg)
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(3):
            upd, state = step(g, state)
        outs[stacked] = (upd, _as_perleaf_tree(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# consumer: accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
def test_accounting_byte_tables_match_across_layouts(quantize):
    params = _params()
    reports = {}
    for stacked in (True, False):
        tx = scale_by_projected_adam(
            _cfg(quantize=quantize, stacked_state=stacked)
        )
        reports[stacked] = optimizer_state_bytes(tx.init(params))
    assert reports[True].total_bytes == reports[False].total_bytes
    assert reports[True].by_category == reports[False].by_category
    assert "projection" in reports[True].by_category


# ---------------------------------------------------------------------------
# consumer: checkpointing (cross-mode restore)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "quantize,state_dtype",
    [(True, jnp.float32), (False, jnp.float32), (False, jnp.bfloat16)],
)
def test_checkpoint_cross_mode_restore(quantize, state_dtype, tmp_path):
    """A checkpoint written in either storage mode restores exactly into a
    template of either mode: the restored arrays equal the source state
    re-expressed in the target layout (pure codec transform)."""
    params = _params()
    g = _grads(params, seed=1)
    treedef = jax.tree_util.tree_structure(params)
    txs, states = {}, {}
    for stacked in (True, False):
        txs[stacked], _, states[stacked] = _run(
            _cfg(quantize=quantize, state_dtype=state_dtype, t_update=2,
                 lam=2, stacked_state=stacked),
            params, g,
        )
    for src in (True, False):
        for dst in (True, False):
            d = str(tmp_path / f"{src}_{dst}")
            ckpt.save(d, 3, states[src])
            template = jax.eval_shape(lambda: txs[dst].init(params))
            restored = ckpt.restore(d, template)
            # expected: the SOURCE state, re-laid-out into dst's structure
            want = states[src].leaves
            want = _as_perleaf_tree(want, treedef)
            got = _as_perleaf_tree(restored.leaves, treedef)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(
                    np.asarray(a.astype(jnp.float32)),
                    np.asarray(b.astype(jnp.float32)),
                )
            np.testing.assert_array_equal(
                np.asarray(restored.count), np.asarray(states[src].count)
            )


def test_stacked_manifest_declares_codec(tmp_path):
    import json

    params = _params()
    tx, _, state = _run(_cfg(stacked_state=True), params, _grads(params))
    d = str(tmp_path)
    ckpt.save(d, 1, state)
    with open(os.path.join(d, "ckpt_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 2
    assert manifest["stacked"], "stacked state must emit stacked entries"
    for se in manifest["stacked"]:
        assert se["codec"] == ss.STACKED_CODEC
        assert se["axis"] == 0
        assert len(se["slots"]) >= 1
    # unknown codec versions must fail loudly, not mis-slice
    se = manifest["stacked"][0]
    se["codec"] = "stacked-bucket/v999"
    with open(os.path.join(d, "ckpt_00000001", "manifest.json"), "w") as f:
        json.dump(manifest, f)
    template = jax.eval_shape(lambda: tx.init(params))
    with pytest.raises(ValueError, match="codec"):
        ckpt.restore(d, template)


# ---------------------------------------------------------------------------
# consumer: cross-pod compression via leaf_view
# ---------------------------------------------------------------------------
def test_compressed_update_stacked_matches_per_leaf():
    """compressed_update on stacked state (leaf_view addressing) must match
    the per-leaf state path — same jnp reduction schedule, state layout
    only differs (floats to XLA-fusion ulp, the A/B standard)."""
    from repro import compat
    from repro.distributed.compression import compressed_update

    params = {f"a{i}": {"w": jnp.zeros((96, 64))} for i in range(3)}
    params["bias"] = jnp.zeros((16,))
    g = _grads(params, seed=2)
    treedef = jax.tree_util.tree_structure(params)
    mesh = jax.make_mesh((1,), ("pod",))
    outs = {}
    for stacked in (True, False):
        cfg = _cfg(t_update=2, lam=2, use_fused_kernel=False,
                   stacked_state=stacked)
        tx = scale_by_projected_adam(cfg)
        state = tx.init(params)

        def per_pod(gg, st):
            return compressed_update(cfg, gg, st, "pod")

        from jax.sharding import PartitionSpec as P

        mapped = compat.shard_map(
            per_pod, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False, axis_names={"pod"},
        )
        for _ in range(3):
            upd, state = jax.jit(mapped)(g, state)
        outs[stacked] = (upd, _as_perleaf_tree(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


def test_compressed_update_stacked_rejects_reordered_tree():
    """A congruent-but-reordered gradient tree (same leaf count and
    shapes, different paths) must raise, never silently pair bucket slices
    with the wrong leaves."""
    from repro.distributed.compression import compressed_update

    params = {"a": {"w": jnp.zeros((96, 64))}, "z": {"w": jnp.zeros((96, 64))}}
    cfg = _cfg(use_fused_kernel=False, stacked_state=True)
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    reordered = {"b": {"w": jnp.zeros((96, 64))},
                 "c": {"w": jnp.zeros((96, 64))}}
    with pytest.raises(ValueError, match="stacked optimizer state"):
        compressed_update(cfg, _grads(reordered), state, "pod")


def test_abstract_accounting_parity_eval_shape():
    """abstract_state_bytes (jax.eval_shape over init — the no-alloc path
    the 314B benchmarks use) must report identical tables for both
    layouts: encode is byte-neutral even on abstract arrays."""
    from repro.core.accounting import abstract_state_bytes

    params = _params()
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    reps = {}
    for stacked in (True, False):
        tx = scale_by_projected_adam(
            _cfg(quantize=True, stacked_state=stacked)
        )
        reps[stacked] = abstract_state_bytes(tx, shapes)
    assert reps[True].total_bytes == reps[False].total_bytes
    assert reps[True].by_category == reps[False].by_category


# ---------------------------------------------------------------------------
# benchmark gate (acceptance criteria)
# ---------------------------------------------------------------------------
def test_state_traffic_gate_llama1b():
    """Pre-stacked storage must remove >=2x of the per-step state bytes
    moved on the LLaMA-1B bucket structure (both int8 and fp32 states), and
    stacking must never *add* traffic."""
    from benchmarks.overhead import state_traffic_report

    for quantize in (True, False):
        rep = state_traffic_report(quantize=quantize)
        assert rep["ratio"] >= 2.0, (quantize, rep["ratio"])
        assert rep["copy_bytes_removed_per_step"] > 0
        for row in rep["buckets"].values():
            assert (
                row["per_step_bytes_stacked_mode"]
                <= row["per_step_bytes_per_leaf_mode"]
            )


def test_state_traffic_gate_measured(monkeypatch):
    """The analytic table above is a model; this gates what the COMPILED
    step actually does: XLA cost_analysis of one whole jitted int8 update
    must access measurably fewer bytes in stacked mode (a regression that
    reintroduces the stack/scatter copies on the hot path drives the
    measured ratio back to ~1.0 and fails here). Pinned to the ref/compiled
    dispatch: interpret-mode Pallas emulation restructures the whole-step
    HLO and is not the shipped program this gate is about."""
    from benchmarks.overhead import measured_state_step_bytes

    monkeypatch.delenv("REPRO_PALLAS", raising=False)
    meas = measured_state_step_bytes(quantize=True)
    assert meas["per_leaf"] > meas["stacked"], meas
    assert meas["ratio"] >= 1.05, meas
    assert meas["bytes_removed_per_step"] > 0
