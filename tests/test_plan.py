"""Budget-driven memory planner (repro/plan, coap-plan/v1).

Covers the PR-5 acceptance criteria and satellites:

  * plan artifact codec: round-trip, unknown versions fail loudly;
  * LLaMA-1B paper vectors: the planned fp32 setting reproduces >=61%
    moment-state reduction and the planned q8 setting >=81%, both against
    the REAL AdamW baseline from ``accounting`` (not the planner's own
    numbers), with predicted bytes matching the constructed optimizer
    EXACTLY;
  * budget behavior: loose -> fp32, tight -> greedy per-bucket quantize
    (genuinely mixed plans), infeasible -> loud error;
  * plan/accounting parity property sweep: on randomized mixed
    matrix+conv+dense trees across fp32/int8/auto and stacked/per-leaf
    layouts, predicted bytes equal ``optimizer_state_bytes`` /
    ``abstract_state_bytes`` byte-for-byte per category;
  * per-bucket knob wiring: mixed-quantize plans produce int8 state in
    exactly the planned buckets; per-bucket ``t_update`` drives distinct
    refresh cadences; mixed overrides within one bucket are rejected;
  * Eqn-6 fallback telemetry: counted per traced (m, n, r) and the
    RuntimeWarning deduplicated per unique (n, r, budget) — the PR-5
    duplicate-warning regression test.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.accounting import (
    CATEGORY_GROUPS,
    abstract_state_bytes,
    optimizer_state_bytes,
)
from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.coap_adam import LeafOverrides, PlanOverrides
from repro.core.stacked_state import StackedLeaves
from repro.plan import (
    PlanInfeasibleError,
    PlanVersionError,
    load_plan,
    save_plan,
    solve,
    verify,
)
from repro.plan.artifact import Plan
from repro.plan.validate import PlanMismatchError, optimizer_config


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _small_tree():
    """Mixed matrix + conv + dense tree, several congruence buckets."""
    return {
        "blk0": {"w": jnp.zeros((96, 64)), "norm": jnp.zeros((64,))},
        "blk1": {"w": jnp.zeros((96, 64)), "norm": jnp.zeros((64,))},
        "wide": {"w": jnp.zeros((64, 160))},
        "tower": {
            "conv0": {"kernel": jnp.zeros((48, 32, 3, 3))},
            "conv1": {"kernel": jnp.zeros((48, 32, 3, 3))},
        },
        "embed": {"table": jnp.zeros((80, 64))},  # excluded -> dense
    }


_SOLVE_KW = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)


def _llama_params():
    from repro.configs import get_config
    from repro.models.model import build_model

    return build_model(get_config("llama-1b")).abstract_params()


# ---------------------------------------------------------------------------
# artifact codec
# ---------------------------------------------------------------------------
def test_plan_artifact_roundtrip(tmp_path):
    plan = solve(_small_tree(), None, arch="toy", **_SOLVE_KW)
    path = str(tmp_path / "plan.json")
    save_plan(plan, path)
    back = load_plan(path)
    assert back.codec == "coap-plan/v1"
    assert back.arch == "toy"
    assert back.budget_bytes == plan.budget_bytes
    assert back.predicted["by_category"] == {
        k: int(v) for k, v in plan.predicted["by_category"].items()
    }
    assert len(back.buckets) == len(plan.buckets)
    for a, b in zip(back.buckets, plan.buckets):
        assert a.spec == b.spec  # ProjSpec survives JSON verbatim
        assert a.paths == b.paths
        assert a.quantize == b.quantize
        assert a.t_update == b.t_update


def test_plan_unknown_codec_fails_loudly(tmp_path):
    plan = solve(_small_tree(), None, **_SOLVE_KW)
    d = plan.to_dict()
    d["codec"] = "coap-plan/v2"
    with pytest.raises(PlanVersionError):
        Plan.from_dict(d)
    d["codec"] = None
    with pytest.raises(PlanVersionError):
        Plan.from_dict(d)


# ---------------------------------------------------------------------------
# LLaMA-1B paper vectors (acceptance criteria)
# ---------------------------------------------------------------------------
def test_llama1b_fp32_vector_exact_and_gated():
    """40GB budget -> fp32 plan; predicted bytes == abstract_state_bytes
    exactly; >=61% moment-state reduction vs the REAL AdamW baseline."""
    from repro.plan import plan_for_arch

    params = _llama_params()
    plan = plan_for_arch("llama-1b", int(40e9))
    assert plan.predicted["n_quantized_buckets"] == 0

    rep = verify(plan, params)  # raises on any byte drift
    assert rep["match"]

    # the REAL baseline, not the planner's own arithmetic
    base_tx = make_optimizer(
        OptimizerConfig(name="adamw", learning_rate=1e-3)
    )
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
    )
    base = abstract_state_bytes(base_tx, shapes)
    assert base.total_bytes == (
        plan.predicted["baseline"]["state_bytes_total"]
    )
    mine = abstract_state_bytes(
        make_optimizer(optimizer_config(plan)), shapes
    )
    assert mine.moment_reduction_vs(base) >= 0.61
    assert abs(
        mine.moment_reduction_vs(base) - plan.predicted["reduction_vs_adamw"]
    ) < 1e-9
    # LLaMA-1B's (n=2048, r=512) buckets exceed the fused-Eqn-6 VMEM
    # budget: the plan must SAY so (counted telemetry satellite).
    proj = [b for b in plan.buckets if b.kind == "project"]
    assert proj and all(b.eqn6_fused is False for b in proj)


def test_llama1b_q8_vector_exact_and_gated():
    from repro.plan import plan_for_arch

    plan = plan_for_arch("llama-1b", None, quantize="force")
    rep = verify(plan, _llama_params())
    assert rep["match"]
    assert plan.predicted["reduction_vs_adamw"] >= 0.81
    assert plan.predicted["n_quantized_buckets"] == len(plan.buckets)


def test_llama1b_tight_budget_forces_mixed_quantize():
    """An intermediate budget quantizes SOME buckets (greedy by bytes
    saved) — and the mixed plan still verifies byte-exactly."""
    from repro.plan import plan_for_arch

    plan = plan_for_arch("llama-1b", int(13.5e9))
    nq = plan.predicted["n_quantized_buckets"]
    assert 0 < nq < len(plan.buckets)
    assert plan.predicted["hbm_total_bytes"] <= int(13.5e9)
    assert verify(plan, _llama_params())["match"]


def test_infeasible_budget_raises():
    from repro.plan import plan_for_arch

    with pytest.raises(PlanInfeasibleError):
        plan_for_arch("llama-1b", int(11e9))


# ---------------------------------------------------------------------------
# plan/accounting parity — property sweep (satellite)
# ---------------------------------------------------------------------------
def _random_tree(rng: np.random.RandomState):
    shapes_mat = [(96, 64), (64, 160), (128, 128), (48, 80)]
    tree = {}
    for i in range(rng.randint(1, 4)):
        m, n = shapes_mat[rng.randint(len(shapes_mat))]
        reps = rng.randint(1, 3)
        for j in range(reps):
            tree[f"blk{i}_{j}"] = {"w": jnp.zeros((m, n))}
    for i in range(rng.randint(0, 3)):
        tree[f"conv{i}"] = {"kernel": jnp.zeros((48, 32, 3, 3))}
    for i in range(rng.randint(0, 3)):
        tree[f"norm{i}"] = jnp.zeros((64,))
    tree["embed"] = {"table": jnp.zeros((80, 64))}
    return tree


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    quantize=st.sampled_from(["off", "force", "auto"]),
    stacked=st.booleans(),
)
def test_predicted_bytes_equal_accounting_property(seed, quantize, stacked):
    """THE parity property: planner-predicted per-category bytes equal
    ``optimizer_state_bytes`` of the concrete constructed optimizer AND
    ``abstract_state_bytes`` of its eval_shape, on randomized mixed trees,
    for fp32 / int8 / auto-mixed codecs and both storage layouts."""
    rng = np.random.RandomState(seed)
    tree = _random_tree(rng)
    budget = None
    if quantize == "auto":
        # interpolate a budget between the all-q8 and all-fp32 plans so
        # the greedy knapsack genuinely mixes codecs
        lo = solve(tree, None, quantize="force", **_SOLVE_KW)
        hi = solve(tree, None, quantize="off", **_SOLVE_KW)
        frac = rng.uniform(0.1, 0.9)
        budget = int(
            lo.predicted["hbm_total_bytes"]
            + frac * (
                hi.predicted["hbm_total_bytes"]
                - lo.predicted["hbm_total_bytes"]
            )
        )
    plan = solve(tree, budget, quantize=quantize, **_SOLVE_KW)
    if not stacked:
        plan.globals_ = dataclasses.replace(
            plan.globals_, stacked_state=False
        )
    tx = make_optimizer(optimizer_config(plan))
    want = dict(plan.predicted["by_category"])

    concrete = optimizer_state_bytes(tx.init(tree))
    assert {k: int(v) for k, v in concrete.by_category.items()} == want

    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree
    )
    assert verify(plan, shapes)["match"]


def test_nondefault_quant_block_flows_into_optimizer():
    """A plan's quant_block must reach the constructed optimizer (the
    artifact's budget math is block-size dependent): with block=64 the
    int8 sidecar is 4x the block-256 one, and the bytes still match
    exactly."""
    tree = _small_tree()
    p256 = solve(tree, None, quantize="force", **_SOLVE_KW)
    p64 = solve(tree, None, quantize="force", quant_block=64, **_SOLVE_KW)
    assert (
        p64.predicted["by_category"]["quant_scales"]
        > p256.predicted["by_category"]["quant_scales"]
    )
    assert verify(p64, tree)["match"]


def test_verify_raises_on_drift():
    plan = solve(_small_tree(), None, **_SOLVE_KW)
    plan.predicted["by_category"] = dict(plan.predicted["by_category"])
    plan.predicted["by_category"]["moments"] += 4
    with pytest.raises(PlanMismatchError):
        verify(plan, _small_tree())


# ---------------------------------------------------------------------------
# per-bucket knob wiring
# ---------------------------------------------------------------------------
def _grads(tree, seed=0):
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), x.shape)
            for i, x in enumerate(flat)
        ],
    )


def test_mixed_quantize_plan_runs_and_stores_planned_codecs():
    """A plan that quantizes only some buckets must produce int8 state in
    EXACTLY those buckets, run fine, and keep updates finite."""
    tree = _small_tree()
    lo = solve(tree, None, quantize="force", **_SOLVE_KW)
    hi = solve(tree, None, quantize="off", **_SOLVE_KW)
    mid = (
        lo.predicted["hbm_total_bytes"] + hi.predicted["hbm_total_bytes"]
    ) // 2
    plan = solve(tree, mid, quantize="auto", **_SOLVE_KW)
    nq = plan.predicted["n_quantized_buckets"]
    assert 0 < nq < len(plan.buckets)

    tx = make_optimizer(optimizer_config(plan))
    state = tx.init(tree)
    # chain: (clip, planned) where planned = chain(projected, lr)
    leaves = state.states[1].states[0].leaves
    assert isinstance(leaves, StackedLeaves)
    # bucket order of the state layout matches the plan's bucket list
    # (both are build_layout under the same rules)
    for bp, bucket_state in zip(plan.buckets, leaves.buckets):
        moment = bucket_state.mu if bp.kind == "dense" else bucket_state.m
        want_dtype = jnp.int8 if bp.quantize else jnp.float32
        assert moment.dtype == want_dtype, (bp.kind, bp.shape, bp.quantize)

    g = _grads(tree)
    step = jax.jit(lambda gg, s: tx.update(gg, s, tree))
    for _ in range(3):
        upd, state = step(g, state)
    for leaf in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_per_bucket_t_update_drives_distinct_cadences():
    """Hand-edit a plan so two buckets carry different T_u: each bucket's
    P must refresh on ITS OWN period (plus the mandatory t=0 init)."""
    tree = {
        "a0": {"w": jnp.zeros((96, 64))},
        "a1": {"w": jnp.zeros((96, 64))},
        "b0": {"w": jnp.zeros((64, 160))},
    }
    plan = solve(tree, None, **_SOLVE_KW)
    proj_is = [
        i for i, b in enumerate(plan.buckets) if b.kind == "project"
    ]
    assert len(proj_is) == 2
    t_us = {plan.buckets[proj_is[0]].shape: 2,
            plan.buckets[proj_is[1]].shape: 3}
    for i in proj_is:
        plan.buckets[i] = dataclasses.replace(
            plan.buckets[i],
            t_update=t_us[plan.buckets[i].shape],
            stagger_groups=1,  # single phase group: refresh at count % T_u
        )

    tx = make_optimizer(optimizer_config(plan))
    state = tx.init(tree)
    g = _grads(tree)
    step = jax.jit(lambda gg, s: tx.update(gg, s, tree))

    def p_of(s, bucket_i):
        return np.asarray(
            s.states[1].states[0].leaves.buckets[bucket_i].p
        )

    prev = {i: p_of(state, i) for i in proj_is}
    changed = {i: [] for i in proj_is}
    for count in range(7):
        _, state = step(g, state)
        for i in proj_is:
            now = p_of(state, i)
            changed[i].append(not np.array_equal(prev[i], now))
            prev[i] = now
    for i in proj_is:
        t_u = plan.buckets[i].t_update
        want = [(c % t_u == 0) or c == 0 for c in range(7)]
        assert changed[i] == want, (
            plan.buckets[i].shape, t_u, changed[i], want
        )


def test_mixed_overrides_within_bucket_rejected():
    """Two congruent leaves (one bucket) with different quantize knobs
    must fail loudly at init."""
    from repro.core.coap_adam import ProjectedAdamConfig, scale_by_projected_adam
    from repro.core.projector import ProjectionRules

    tree = {"a": {"w": jnp.zeros((96, 64))}, "b": {"w": jnp.zeros((96, 64))}}
    cfg = ProjectedAdamConfig(
        rules=ProjectionRules(rank=16, min_dim=16),
        overrides=PlanOverrides(entries=(
            ("a/w", LeafOverrides(quantize=True)),
            ("b/w", LeafOverrides(quantize=False)),
        )),
    )
    with pytest.raises(ValueError, match="disagree within bucket"):
        scale_by_projected_adam(cfg).init(tree)


def test_compression_overrides_uniform_divergent_and_mixed():
    """compressed_update must ACCEPT solver-produced overrides (they
    restate the global T_u on every bucket — normalization, not identity,
    decides uniformity), ACCEPT a bucket pinned to a genuinely different
    cadence (per-bucket T_u is native now: the schedule tables are
    per-leaf), and REJECT overrides that disagree WITHIN one congruence
    bucket with an error naming the offending paths."""
    from repro.core.coap_adam import (
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.distributed.compression import compressed_update
    from repro.plan.apply import plan_overrides, planned_rules

    tree = _small_tree()
    plan = solve(tree, None, **_SOLVE_KW)
    g = plan.globals_
    cfg = ProjectedAdamConfig(
        rules=planned_rules(plan), t_update=g.t_update, lam=g.lam,
        stagger_groups=g.stagger_groups, overrides=plan_overrides(plan),
    )
    state = scale_by_projected_adam(cfg).init(tree)
    grads = _grads(tree)
    try:
        compressed_update(cfg, grads, state, "pod")
    except ValueError:
        pytest.fail("uniform plan overrides must pass the guard")
    except Exception:
        pass  # pmean outside shard_map — the guard itself already passed

    # Whole bucket pinned to a different cadence: supported natively (the
    # compressed schedule is per-leaf; test_distributed pins the cadence
    # parity against the core transform).
    divergent = dataclasses.replace(
        cfg,
        overrides=PlanOverrides(entries=(
            ("blk0/w", LeafOverrides(t_update=g.t_update + 1)),
            ("blk1/w", LeafOverrides(t_update=g.t_update + 1)),
        )),
    )
    try:
        compressed_update(divergent, grads, state, "pod")
    except ValueError:
        pytest.fail("per-bucket t_update overrides are supported natively")
    except Exception:
        pass  # pmean outside shard_map again

    # Same override on only ONE member of the (blk0/w, blk1/w) bucket:
    # genuinely mixed — loud ValueError naming both sides.
    mixed = dataclasses.replace(
        cfg,
        overrides=PlanOverrides(entries=(
            ("blk0/w", LeafOverrides(t_update=g.t_update + 1)),
        )),
    )
    with pytest.raises(ValueError, match="blk0/w"):
        compressed_update(mixed, grads, state, "pod")


def test_plan_sync_codes_ef_sidecar_byte_exact():
    """solve(sync_codes=True) prices the int8-collective error-feedback
    sidecar (fp32 per projected/conv moment core) and the plan STILL
    verifies byte-exactly against the constructed optimizer — init_fn
    allocates exactly the accumulators the byte model predicts."""
    tree = _small_tree()
    base = solve(tree, None, **_SOLVE_KW)
    plan = solve(tree, None, sync_codes=True, **_SOLVE_KW)

    pred = plan.predicted["by_category"]
    assert pred.get("ef_sidecar", 0) > 0, pred
    assert base.predicted["by_category"].get("ef_sidecar", 0) == 0
    # the sidecar is the ONLY delta between the two plans
    deltas = {
        k: pred.get(k, 0) - base.predicted["by_category"].get(k, 0)
        for k in set(pred) | set(base.predicted["by_category"])
    }
    assert {k: v for k, v in deltas.items() if v} == {
        "ef_sidecar": pred["ef_sidecar"]
    }, deltas

    assert verify(plan, tree)["match"]
    # the knob survives the artifact codec round-trip
    assert Plan.from_dict(plan.to_dict()).globals_.sync_codes is True
    assert Plan.from_dict(base.to_dict()).globals_.sync_codes is False


# ---------------------------------------------------------------------------
# accounting split (satellite)
# ---------------------------------------------------------------------------
def test_accounting_groups_and_moment_denominator():
    """AdamW's mu/nu now categorize as moment state (totals unchanged);
    CATEGORY_GROUPS partitions every category; moment_reduction_vs
    excludes projector bytes from both sides."""
    tree = _small_tree()
    base = optimizer_state_bytes(
        make_optimizer(
            OptimizerConfig(name="adamw", learning_rate=1e-3)
        ).init(tree)
    )
    n_par = sum(x.size for x in jax.tree_util.tree_leaves(tree))
    assert base.grouped()["moment_state"] == 2 * n_par * 4
    assert sum(base.grouped().values()) == base.total_bytes

    coap = optimizer_state_bytes(
        make_optimizer(
            OptimizerConfig(name="coap-adamw", learning_rate=1e-3,
                            rank=16, min_dim=16)
        ).init(tree)
    )
    assert coap.projector_bytes > 0
    assert sum(coap.grouped().values()) == coap.total_bytes
    # P excluded from both sides: the moment denominator reduction must
    # exceed the total-bytes reduction (P only hurts the latter).
    assert coap.moment_reduction_vs(base) > coap.reduction_vs(base)
    assert set(CATEGORY_GROUPS.values()) == {
        "moment_state", "projector", "quant_sidecar", "other"
    }


# ---------------------------------------------------------------------------
# Eqn-6 fallback telemetry + warning dedupe (satellites)
# ---------------------------------------------------------------------------
def test_eqn6_fallback_counts_and_warning_dedupe(monkeypatch):
    """Fallbacks are counted per traced (m, n, r); the RuntimeWarning is
    emitted once per unique (n, r, budget) — not per trace (the PR-5
    duplicate-noise regression)."""
    from repro.kernels import eqn6 as eqn6_mod
    from repro.kernels import ops as kops

    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    monkeypatch.setenv(eqn6_mod._VMEM_ENV, "1024")  # nothing fits
    kops.reset_eqn6_fallbacks()

    def refresh(m, n, r, seed):
        k = jax.random.key(seed)
        g = jax.random.normal(jax.random.fold_in(k, 0), (m, n))
        p = jax.random.normal(jax.random.fold_in(k, 1), (n, r)) / np.sqrt(r)
        mp = 0.1 * jax.random.normal(jax.random.fold_in(k, 2), (m, r))
        return kops.eqn6_sgd_update(p, g, mp)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        refresh(64, 48, 8, 0)
        refresh(64, 48, 8, 1)  # same shape: counted, NOT re-warned
        refresh(96, 48, 8, 2)  # same (n, r): counted, NOT re-warned
        refresh(64, 32, 8, 3)  # new (n, r): fresh warning
    runtime = [w for w in caught if "Eqn-6" in str(w.message)]
    assert len(runtime) == 2, [str(w.message) for w in runtime]
    counts = kops.eqn6_fallback_counts()
    assert counts[(64, 48, 8)] == 2
    assert counts[(96, 48, 8)] == 1
    assert counts[(64, 32, 8)] == 1

    kops.reset_eqn6_fallbacks()
    assert kops.eqn6_fallback_counts() == {}
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        refresh(64, 48, 8, 4)  # after reset the warning fires again
    assert any(
        issubclass(w.category, RuntimeWarning) for w in caught
    )


def test_plan_records_eqn6_fallback_buckets():
    """verify() surfaces the per-bucket fused-Eqn-6 fallback prediction."""
    plan = solve(_small_tree(), None, **_SOLVE_KW)
    rep = verify(plan, _small_tree())
    # small shapes all fit the default 16MiB budget -> no fallbacks
    assert rep["eqn6_fallback_buckets"] == []
    tight = solve(_small_tree(), None, vmem_budget=1024, **_SOLVE_KW)
    assert any(b.eqn6_fused is False for b in tight.buckets)


# ---------------------------------------------------------------------------
# benchmark gate (acceptance criteria)
# ---------------------------------------------------------------------------
def test_plan_gates_llama1b_paper_vectors():
    """BENCH_plan methodology: planned fp32 >=61%, planned q8 >=81%
    moment-state reduction vs AdamW on LLaMA-1B (paper Tables 5/6)."""
    from benchmarks.overhead import plan_report

    rep = plan_report(fast=True)  # fast: gates only, no re-verify
    assert rep["fp32"]["reduction_vs_adamw"] >= 0.61, rep["fp32"]
    assert rep["q8"]["reduction_vs_adamw"] >= 0.81, rep["q8"]
    assert rep["fp32"]["n_quantized_buckets"] == 0
    assert rep["q8"]["n_quantized_buckets"] == rep["q8"]["n_buckets"]


def test_plan_cli_budget_parsing():
    from repro.launch.plan import parse_budget

    assert parse_budget("40GB") == 40 * 10**9
    assert parse_budget("512MiB") == 512 * 2**20
    assert parse_budget("123") == 123
    assert parse_budget("1.5e9") == int(1.5e9)
    assert parse_budget("auto") is None
    with pytest.raises(ValueError):
        parse_budget("forty gigs")


def test_plan_cli_end_to_end(tmp_path):
    from repro.launch import plan as plan_cli

    out = str(tmp_path / "llama.json")
    plan_cli.main([
        "--arch", "llama-1b", "--budget", "40GB", "--out", out, "--verify",
    ])
    back = load_plan(out)
    assert back.arch == "llama-1b"
    assert back.predicted["reduction_vs_adamw"] >= 0.61
