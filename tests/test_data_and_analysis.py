"""Data substrate + HLO-analysis units: tokenizer roundtrip, Markov stream
statistics, collective factor arithmetic, replica-group parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import SyntheticLM, synthetic_batch
from repro.data.tokenizer import ByteTokenizer
from repro.launch import hlo_analysis as H


@settings(max_examples=20, deadline=None)
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text)
    assert ids[0] == tok.bos
    assert tok.decode(ids) == text.encode("utf-8", errors="replace").decode(
        "utf-8", errors="replace"
    )


def test_synthetic_batch_deterministic_and_shifted():
    a = synthetic_batch(3, 4, 16, 256, seed=1)
    b = synthetic_batch(3, 4, 16, 256, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    full_a = synthetic_batch(3, 4, 16, 256, seed=1)
    np.testing.assert_array_equal(np.asarray(a["labels"][:, :-1]),
                                  np.asarray(a["tokens"][:, 1:]))
    c = synthetic_batch(4, 4, 16, 256, seed=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_markov_stream_follows_table():
    data = SyntheticLM(vocab=64, order=1, noise=0.0, seed=3)
    b = data.batch(0, batch=4, seq=32)
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    # with zero noise every next token is table[0][prev]
    pred = data.table[0][toks]
    np.testing.assert_array_equal(labels, pred)


def test_markov_ce_floor_monotone_in_noise():
    floors = [SyntheticLM(vocab=64, order=1, noise=n).ce_floor()
              for n in (0.01, 0.1, 0.3)]
    assert floors[0] < floors[1] < floors[2]


@pytest.mark.parametrize("op,k,expect", [
    ("all-reduce", 4, 2 * 3 / 4),
    ("all-gather", 4, 3 / 4),
    ("reduce-scatter", 4, 3.0),
    ("all-to-all", 8, 7 / 8),
    ("collective-permute", 16, 1.0),
])
def test_collective_ring_factors(op, k, expect):
    assert H._COLL_FACTORS[op](k) == pytest.approx(expect)


def test_replica_group_parsing():
    assert H._group_size("replica_groups=[32,16]<=[512]", 0) == 16
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 0) == 4
    assert H._group_size("no groups here", 7) == 7


def test_shape_bytes_parsing():
    assert H._type_bytes("f32[4,8]{1,0}") == 128
    assert H._type_bytes("bf16[10]") == 20
    assert H._type_bytes("(f32[2,2], s8[4])") == 20
    assert H._type_bytes("pred[]") == 1  # scalar: one element


def test_analyze_counts_dot_flops_exactly():
    co = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((64, 128)), jnp.zeros((128, 32))).compile()
    a = H.analyze(co.as_text())
    assert a["flops"] == pytest.approx(2 * 64 * 128 * 32)
