"""Shared test config: a minimal ``hypothesis`` fallback shim.

Several test modules import ``hypothesis`` for property sweeps. The target
container does not ship it (and nothing may be pip-installed), so when the
real package is absent we register a tiny deterministic stand-in in
``sys.modules`` *before* collection. The shim reproduces the small API
surface these tests use — ``given``, ``settings`` and the ``integers`` /
``floats`` / ``sampled_from`` / ``text`` / ``booleans`` /
``dictionaries`` strategies (plus ``.map``) — and
runs each property a bounded number of deterministic examples (seeded by
the test name, edge cases first). With the real hypothesis installed the
shim is inert.
"""
from __future__ import annotations

import random
import string
import sys
import types


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, edge_examples, draw):
            self._edges = list(edge_examples)
            self._draw = draw

        def example(self, rng: random.Random, i: int):
            if i < len(self._edges):
                return self._edges[i]
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(
                [fn(e) for e in self._edges],
                lambda rng: fn(self._draw(rng)),
            )

    def integers(min_value=0, max_value=1 << 16):
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.randint(min_value, max_value),
        )

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.uniform(min_value, max_value),
        )

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            elements, lambda rng: elements[rng.randrange(len(elements))]
        )

    def booleans():
        return _Strategy([False, True], lambda rng: bool(rng.getrandbits(1)))

    def dictionaries(keys, values, min_size=0, max_size=4):
        max_size = 4 if max_size is None else max_size

        def draw(rng: random.Random):
            out = {}
            for _ in range(rng.randint(min_size, max_size)):
                out[keys.example(rng, 1 << 30)] = values.example(rng, 1 << 30)
            return out

        edges = [{}] if min_size == 0 else []
        return _Strategy(edges, draw)

    def text(alphabet=None, min_size=0, max_size=20):
        chars = (
            list(alphabet)
            if alphabet is not None
            else list(string.ascii_letters + string.digits + " .,!?-_\n")
        )
        max_size = 20 if max_size is None else max_size

        def draw(rng: random.Random):
            k = rng.randint(min_size, max_size)
            return "".join(rng.choice(chars) for _ in range(k))

        edges = []
        if min_size == 0:
            edges.append("")
        return _Strategy(edges, draw)

    _MAX_EXAMPLES_CAP = 6  # keep the deterministic sweep fast in CI

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            def wrapper():
                n = min(
                    getattr(wrapper, "_shim_max_examples", _MAX_EXAMPLES_CAP),
                    _MAX_EXAMPLES_CAP,
                )
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    args = [s.example(rng, i) for s in arg_strategies]
                    kwargs = {
                        k: s.example(rng, i) for k, s in kw_strategies.items()
                    }
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.hypothesis_shim = True
            return wrapper

        return decorate

    def settings(max_examples=None, deadline=None, **_kw):
        def decorate(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn

        return decorate

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.text = text
    st.dictionaries = dictionaries
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()
