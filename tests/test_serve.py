"""Serving engine: batched generation over dense/SWA/MLA/SSM caches."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.models.model import build_model
from repro.serve import ServeConfig, ServeEngine


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",       # dense KV
    "mixtral-8x22b",        # rolling SWA ring
    "minicpm3-4b",          # MLA latent cache
    "mamba2-2.7b",          # SSM state
    "zamba2-1.2b",          # hybrid
])
def test_generate_batched(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, ServeConfig(max_new_tokens=5))
    prompts = [[1, 2, 3, 4], [7, 8, 9, 10, 11, 12]]
    outs = engine.generate(prompts)
    assert len(outs) == 2
    for p, o in zip(prompts, outs):
        assert o[: len(p)] == p
        assert len(o) == len(p) + 5
        assert all(0 <= t < cfg.vocab_size for t in o)


def test_generate_deterministic_greedy():
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, ServeConfig(max_new_tokens=6))
    a = engine.generate([[5, 6, 7]])
    b = engine.generate([[5, 6, 7]])
    assert a == b


def test_generate_matches_uncached_forward():
    """Greedy continuation via the engine == greedy argmax over repeated
    full forwards (the gold-standard correctness check for the cache path)."""
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    prompt = [3, 1, 4, 1, 5]
    steps = 4
    engine = ServeEngine(model, params, ServeConfig(max_new_tokens=steps))
    got = engine.generate([prompt])[0]

    seq = list(prompt)
    for _ in range(steps):
        batch = {"tokens": jnp.asarray([seq], jnp.int32)}
        logits, _, _ = model.logits(params, batch)
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert got == seq, (got, seq)
