"""LoRA baseline: identity at init, adapter-only training, memory shape."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.accounting import optimizer_state_bytes
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.lora import (LoRAConfig, adapter_bytes, lora_init,
                               lora_merge, make_lora_loss)
from repro.models.model import build_model
from repro.optim import apply_updates


def _setup():
    cfg = dataclasses.replace(get_smoke("llama-1b"), dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lcfg = LoRAConfig(rank=4, alpha=8.0, min_dim=32)
    adapters = lora_init(jax.random.key(1), params, lcfg)
    return cfg, model, params, lcfg, adapters


def test_identity_at_init():
    """B=0 ⇒ merged params == frozen params exactly."""
    _, model, params, lcfg, adapters = _setup()
    merged = lora_merge(params, adapters, lcfg)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adapter_training_reduces_loss_and_freezes_base():
    cfg, model, params, lcfg, adapters = _setup()
    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.1)
    loss_fn = make_lora_loss(model, params, lcfg)
    tx = make_optimizer(OptimizerConfig(name="adamw", learning_rate=5e-3))
    state = tx.init(adapters)

    @jax.jit
    def step(ad, s, b):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(ad, b)
        u, s = tx.update(g, s, ad)
        return apply_updates(ad, u), s, loss

    first = None
    for i in range(40):
        adapters, state, loss = step(adapters, state, data.batch(i, 8, 32))
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))
    # optimizer state covers ONLY adapters (≪ full-model Adam)
    full_tx = make_optimizer(OptimizerConfig(name="adamw", learning_rate=5e-3))
    full_bytes = optimizer_state_bytes(full_tx.init(params)).total_bytes
    lora_bytes = optimizer_state_bytes(state).total_bytes
    assert lora_bytes < 0.5 * full_bytes, (lora_bytes, full_bytes)
    assert adapter_bytes(adapters) > 0


def test_stacked_and_excluded_leaves():
    _, model, params, lcfg, adapters = _setup()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        adapters,
        is_leaf=lambda x: x is None or (isinstance(x, dict)
                                        and set(x) == {"A", "B"}))
    from repro.core.projector import path_str
    kinds = {path_str(kp): v for kp, v in flat}
    # stacked attention weights adapted; norms/embeddings not
    assert any(v is not None and "stack" in k for k, v in kinds.items())
    assert all(v is None for k, v in kinds.items() if "norm" in k or "embed" in k)
