"""Eqn 7 (low-cost SVD) vs full SVD: subspace recovery on low-rank gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import recalibrate


def _lowrank_gradient(m, n, true_rank, seed=0, noise=1e-3):
    """Gradients during training are approximately low-rank (paper §3.1)."""
    key = jax.random.key(seed)
    a = jax.random.normal(jax.random.fold_in(key, 1), (m, true_rank))
    b = jax.random.normal(jax.random.fold_in(key, 2), (true_rank, n))
    eps = noise * jax.random.normal(jax.random.fold_in(key, 3), (m, n))
    return a @ b + eps


def test_lowcost_svd_recovers_true_subspace():
    m, n, r = 128, 96, 8
    g = _lowrank_gradient(m, n, r)
    p_prev = jax.random.normal(jax.random.key(9), (n, r)) / np.sqrt(r)
    p = recalibrate.lowcost_svd(g, p_prev)
    p_full = recalibrate.galore_svd(g, r)
    # Both should span the same top-r right-singular subspace.
    overlap = recalibrate.subspace_overlap(p, p_full)
    assert float(overlap) > 0.99, float(overlap)


def test_lowcost_svd_orthonormal_columns():
    g = _lowrank_gradient(64, 48, 6, seed=3)
    p_prev = jax.random.normal(jax.random.key(1), (48, 6))
    p = recalibrate.lowcost_svd(g, p_prev)
    ptp = p.T @ p
    np.testing.assert_allclose(ptp, jnp.eye(6), atol=1e-5)


def test_lowcost_svd_reconstruction_beats_random():
    g = _lowrank_gradient(96, 64, 8, seed=5, noise=0.05)
    p_prev = jax.random.normal(jax.random.key(2), (64, 8)) / np.sqrt(8)
    p = recalibrate.lowcost_svd(g, p_prev)
    def recon_err(pp):
        g_hat = g @ pp @ pp.T
        return float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert recon_err(p) < recon_err(p_prev) * 0.5


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(16, 96),
    n=st.integers(16, 80),
    r=st.integers(2, 8),
    seed=st.integers(0, 100),
)
def test_lowcost_svd_batched_and_shapes(m, n, r, seed):
    r = min(r, min(m, n) - 1)
    g = jnp.stack([_lowrank_gradient(m, n, r, seed=seed + i) for i in range(2)])
    p_prev = jax.random.normal(jax.random.key(seed), (2, n, r))
    p = recalibrate.lowcost_svd(g, p_prev)
    assert p.shape == (2, n, r)
    assert bool(jnp.all(jnp.isfinite(p)))


def test_galore_svd_is_top_right_singular_vectors():
    g = _lowrank_gradient(64, 32, 4, seed=8, noise=0.0)
    p = recalibrate.galore_svd(g, 4)
    # Projection onto P must preserve essentially all of G's energy.
    g_hat = g @ p @ p.T
    rel = jnp.linalg.norm(g - g_hat) / jnp.linalg.norm(g)
    assert float(rel) < 1e-4
