"""§Perf model variants must be numerically faithful to the baselines:
chunked/flash(tagged) attention, absorbed MLA decode, local-EP MoE,
bf16-elementwise mode, and the HLO cost model itself."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import attention as A
from repro.models.layers import materialize, set_pure_bf16
from repro.models.model import build_model


def test_mla_absorbed_matches_naive():
    d, H = 64, 4
    kw = dict(n_heads=H, q_lora=32, kv_lora=16, qk_nope=8, qk_rope=8, v_head=8)
    params = materialize(A.mla_defs(d, H, 32, 16, 8, 8, 8), jax.random.key(0))
    B, T = 2, 9
    x = 0.3 * jax.random.normal(jax.random.key(1), (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    c1 = A.mla_init_cache(B, T + 2, 16, 8, jnp.float32)
    c2 = A.mla_init_cache(B, T + 2, 16, 8, jnp.float32)
    _, c1 = A.mla_apply(params, x[:, :-1], pos[:, :-1], cache=c1, **kw)
    _, c2 = A.mla_apply(params, x[:, :-1], pos[:, :-1], cache=c2, **kw)
    a, _ = A.mla_apply(params, x[:, -1:], pos[:, -1:], cache=c1, **kw)
    b, _ = A.mla_apply(params, x[:, -1:], pos[:, -1:], cache=c2,
                       absorbed_decode=True, **kw)
    np.testing.assert_allclose(a, b, atol=2e-5)


def test_chunked_attention_matches_naive_with_grads():
    d, H, K, hd = 64, 8, 2, 16
    params = materialize(A.gqa_defs(d, H, K, hd), jax.random.key(0))
    B, T = 2, 600
    x = 0.3 * jax.random.normal(jax.random.key(1), (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    kw = dict(n_heads=H, n_kv=K, head_dim=hd, window=77)

    def loss(p, impl):
        o, _ = A.gqa_apply(p, x, pos, attn_impl=impl, **kw)
        return jnp.sum(o**2)

    np.testing.assert_allclose(loss(params, "chunked"), loss(params, "naive"),
                               rtol=1e-5)
    ga = jax.grad(loss)(params, "naive")
    gb = jax.grad(loss)(params, "chunked")
    for k in ga:
        np.testing.assert_allclose(gb[k], ga[k], atol=5e-4, rtol=2e-3)


def test_bf16_elementwise_close_to_fp32_path():
    """Pure-bf16 norms/activations stay within bf16 tolerance of the
    fp32-upcast baseline on a full model forward."""
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                          cfg.vocab_size)}
    base, _, _ = model.logits(params, batch)
    cfg2 = dataclasses.replace(cfg, bf16_elementwise=True)
    model2 = build_model(cfg2)
    opt, _, _ = model2.logits(params, batch)
    set_pure_bf16(False)
    a = base.astype(jnp.float32)
    b = opt.astype(jnp.float32)
    assert jnp.argmax(a[:, -1], -1).tolist() == jnp.argmax(b[:, -1], -1).tolist()
    corr = jnp.mean(jnp.sum(a * b, -1) / (jnp.linalg.norm(a, axis=-1)
                                          * jnp.linalg.norm(b, axis=-1)))
    assert float(corr) > 0.995, float(corr)


def test_local_ep_moe_fallback_single_device():
    """Without a mesh, local_ep must equal the plain dispatch exactly."""
    from repro.models import moe as E

    params = materialize(E.moe_defs(32, 64, 4), jax.random.key(0))
    x = 0.3 * jax.random.normal(jax.random.key(1), (2, 8, 32))
    a, aux_a = E.moe_apply(params, x, n_experts=4, top_k=2,
                           capacity_factor=4.0)
    b, aux_b = E.moe_apply_local_ep(params, x, n_experts=4, top_k=2,
                                    capacity_factor=4.0)
    np.testing.assert_allclose(a, b, atol=1e-6)
    np.testing.assert_allclose(aux_a, aux_b, atol=1e-6)


def test_hlo_cost_model_scan_and_cond():
    """The roofline's cost model must multiply scan bodies by trip count and
    split conditional branches (COAP refresh amortization)."""
    from repro.launch import hlo_analysis as H

    def f(w, x, flag):
        def body(h, wi):
            return jnp.tanh(h @ wi), None

        h, _ = jax.lax.scan(body, x, w, length=8)
        extra = jax.lax.cond(flag, lambda: (h @ h.T).sum(), lambda: h.sum())
        return h.sum() + extra

    co = jax.jit(f).lower(jnp.zeros((8, 128, 128)), jnp.zeros((4, 128)),
                          True).compile()
    a = H.analyze(co.as_text())
    np.testing.assert_allclose(a["flops"], 8 * 2 * 4 * 128 * 128, rtol=1e-6)
    np.testing.assert_allclose(a["flops_cond"], 2 * 4 * 4 * 128, rtol=1e-6)


def test_hlo_cost_model_region_boundary():
    """Kernel-region accounting: in-region intermediates don't count."""
    from repro.launch import hlo_analysis as H

    def f(q, k):
        with jax.named_scope("PALLAS_FLASH_REGION"):
            s = q @ k.T
            p = jax.nn.softmax(s, axis=-1)
            o = p @ k
        return o.sum()

    co = jax.jit(f).lower(jnp.zeros((256, 64)), jnp.zeros((256, 64))).compile()
    a = H.analyze(co.as_text())
    co2 = jax.jit(lambda q, k: (jax.nn.softmax(q @ k.T, -1) @ k).sum()).lower(
        jnp.zeros((256, 64)), jnp.zeros((256, 64))).compile()
    b = H.analyze(co2.as_text())
    # same flops, strictly fewer counted bytes inside the region
    np.testing.assert_allclose(a["flops"], b["flops"], rtol=1e-6)
    assert a["hbm_bytes"] < 0.7 * b["hbm_bytes"], (a["hbm_bytes"], b["hbm_bytes"])
