"""GPipe pipeline stage == sequential execution (8 host devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body, devices=8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    return proc.stdout


def test_pipeline_matches_sequential():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, split_stage_params

        L, D = 8, 32
        key = jax.random.key(0)
        params = {"w": 0.3 * jax.random.normal(key, (L, D, D)),
                  "b": 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                                 (L, D))}
        def layer(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        def stage_fn(stage_params, h):
            def body(hh, p):
                return layer(p, hh), None
            h, _ = jax.lax.scan(body, h, stage_params)
            return h

        x = jax.random.normal(jax.random.fold_in(key, 2), (6, 4, D))  # 6 micro

        # sequential reference
        ref = jax.vmap(lambda mb: stage_fn(params, mb))(x)

        for n_stages in (2, 4):
            mesh = jax.make_mesh((n_stages, 8 // n_stages), ("pod", "data"))
            sp = split_stage_params(params, n_stages)
            out = pipeline_apply(stage_fn, sp, x, mesh=mesh, axis="pod")
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-6)
            print(f"pipeline {n_stages} stages ok")
    """)
