"""Algorithm 1/2 integration: schedules, memory ordering, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accounting import optimizer_state_bytes, abstract_state_bytes
from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.coap_adam import ProjLeaf
from repro.optim import apply_updates


def _params():
    return {
        "blk": {"w": jnp.zeros((4, 192, 256)), "norm_scale": jnp.ones((4, 192))},
        "embed": {"embedding": 0.02 * jnp.ones((512, 192))},
    }


def _tx(name, **kw):
    kw.setdefault("rank", 32)
    kw.setdefault("t_update", 4)
    kw.setdefault("lam", 2)
    kw.setdefault("learning_rate", 1e-3)
    return make_optimizer(OptimizerConfig(name=name, **kw))


ALL_NAMES = [
    "adamw",
    "adafactor",
    "coap-adamw",
    "galore-adamw",
    "flora-adamw",
    "coap-adafactor",
    "galore-adafactor",
    "8bit-coap-adamw",
    "8bit-adamw",
]


@pytest.mark.parametrize("name", ALL_NAMES)
def test_optimizer_runs_and_is_finite(name):
    params = _params()
    tx = _tx(name)
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, params))
    g = jax.tree_util.tree_map(lambda p: 0.1 * jnp.ones_like(p), params)
    for _ in range(5):
        upd, state = step(g, state)
    for leaf in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_memory_ordering_matches_paper():
    """COAP < Adam; 8-bit COAP < COAP; COAP == GaLore state size (Table 5)."""
    params = _params()
    sizes = {}
    for name in ["adamw", "coap-adamw", "galore-adamw", "8bit-coap-adamw"]:
        tx = _tx(name)
        sizes[name] = optimizer_state_bytes(tx.init(params)).total_bytes
    assert sizes["coap-adamw"] < 0.75 * sizes["adamw"]
    assert sizes["8bit-coap-adamw"] < 0.45 * sizes["coap-adamw"]
    assert sizes["coap-adamw"] == sizes["galore-adamw"]


def test_abstract_accounting_no_allocation():
    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), _params()
    )
    tx = _tx("coap-adamw")
    rep = abstract_state_bytes(tx, shapes)
    concrete = optimizer_state_bytes(tx.init(_params()))
    assert rep.total_bytes == concrete.total_bytes


def _find_proj_leaves(state):
    out = []

    def walk(node):
        if isinstance(node, ProjLeaf):
            out.append(node)
            return
        if isinstance(node, (list, tuple)):
            for c in node:
                walk(c)
        elif isinstance(node, dict):
            for c in node.values():
                walk(c)
        elif hasattr(node, "_fields"):
            for f in node._fields:
                walk(getattr(node, f))

    walk(state)
    return out


def test_p_refresh_follows_t_u_schedule():
    """P must change exactly at steps ≡ 0 (mod T_u) — Algorithm 1 lines 3-8.

    NOTE: uses unclipped gradients — Eqn 6's gradient scales with ‖G‖², so a
    global-norm-clipped gradient makes the SGD refresh numerically invisible
    (that scale-sensitivity is a property of the paper's objective; see the
    ``eqn6_normalize`` beyond-paper option).
    """
    params = _params()
    tx = _tx("coap-adamw", t_update=3, lam=2, grad_clip=None)
    state = tx.init(params)
    key = jax.random.key(0)
    step = jax.jit(lambda g, s: tx.update(g, s, params))
    prev_p = None
    for i in range(8):
        g = jax.tree_util.tree_map(
            lambda p: jax.random.normal(jax.random.fold_in(key, i), p.shape), params
        )
        _, state = step(g, state)
        p_now = _find_proj_leaves(state)[0].p
        if prev_p is not None:
            changed = bool(jnp.max(jnp.abs(p_now - prev_p)) > 1e-7)
            should_change = (i % 3) == 0  # count was i when this step ran
            assert changed == should_change, (i, changed, should_change)
        prev_p = p_now


def test_coap_converges_on_quadratic():
    """COAP must track Adam on a simple least-squares problem (paper: same
    PPL as AdamW at −61% memory). Flora at the same rank should be worse."""
    key = jax.random.key(0)
    m, n = 96, 64
    w_star = jax.random.normal(jax.random.fold_in(key, 1), (m, n))

    def loss(params):
        return jnp.mean((params["blk"]["w"] - w_star) ** 2)

    results = {}
    for name in ["coap-adamw", "flora-adamw", "galore-adamw"]:
        params = {"blk": {"w": jnp.zeros((m, n))}}
        tx = _tx(name, learning_rate=3e-2, rank=16, t_update=10, lam=5,
                 grad_clip=None, min_dim=8)
        state = tx.init(params)

        @jax.jit
        def step(params, state):
            g = jax.grad(loss)(params)
            upd, state = tx.update(g, state, params)
            return apply_updates(params, upd), state

        for _ in range(300):
            params, state = step(params, state)
        results[name] = float(loss(params))
    init_loss = float(jnp.mean(w_star**2))
    # COAP reduces the loss >20x from init and beats both baselines at the
    # same rank/interval (the paper's Fig 3 / Table 7 ordering).
    assert results["coap-adamw"] < 0.05 * init_loss, results
    assert results["coap-adamw"] < results["flora-adamw"], results
    assert results["coap-adamw"] < results["galore-adamw"], results


def test_quantized_states_track_fp32():
    """8-bit COAP update directions must stay close to fp32 COAP."""
    params = {"blk": {"w": jnp.zeros((128, 96))}}
    g = 0.1 * jax.random.normal(jax.random.key(3), (128, 96))
    grads = {"blk": {"w": g}}
    outs = {}
    for name in ["coap-adamw", "8bit-coap-adamw"]:
        tx = _tx(name, rank=16, grad_clip=None)
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, params))
        upd = None
        for _ in range(3):
            upd, state = step(grads, state)
        outs[name] = upd["blk"]["w"]
    a, b = outs["coap-adamw"], outs["8bit-coap-adamw"]
    cos = jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b))
    assert float(cos) > 0.95, float(cos)


def test_galore_update_scale_default():
    """GaLore wrapper defaults to its repo's α=0.25 update scaling."""
    params = {"blk": {"w": jnp.zeros((96, 64))}}
    g = {"blk": {"w": 0.1 * jax.random.normal(jax.random.key(0), (96, 64))}}
    u = {}
    for name in ["coap-adamw", "galore-adamw"]:
        tx = _tx(name, rank=16, grad_clip=None, learning_rate=1.0, t_update=1000,
                 min_dim=8)
        state = tx.init(params)
        upd, _ = jax.jit(lambda gg, s: tx.update(gg, s, params))(g, state)
        u[name] = upd["blk"]["w"]
    ratio = float(jnp.linalg.norm(u["galore-adamw"]) / jnp.linalg.norm(u["coap-adamw"]))
    assert 0.15 < ratio < 0.35, ratio
