"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement).
Full configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, get_smoke, input_specs, list_archs
from repro.configs.base import supports_shape
from repro.core.api import OptimizerConfig, make_optimizer
from repro.models.model import build_model
from repro.optim import apply_updates

ARCHS = list_archs()


def _smoke_batch(cfg, b=2, t=16, key=jax.random.key(0)):
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = 0.1 * jax.random.normal(key, (b, t, cfg.d_model)).astype(cfg.dtype)
    else:
        batch["tokens"] = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        batch["positions"] = jnp.stack([pos, pos, pos])
    if cfg.encoder_layers:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)
        ).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg)
    logits, _, aux = jax.jit(model.logits)(params, batch)
    b = 2
    t = 16
    assert logits.shape == (b, t, cfg.vocab_size), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_coap_train_step(arch):
    """End-to-end: loss -> grads -> COAP update -> params move, no NaNs."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tx = make_optimizer(
        OptimizerConfig(name="coap-adamw", learning_rate=1e-3, rank=8,
                        t_update=2, lam=2, min_dim=16)
    )
    opt_state = tx.init(params)
    batch = _smoke_batch(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        updates, opt_state = tx.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    p1, opt_state, loss1 = step(params, opt_state, batch)
    p2, opt_state, loss2 = step(p1, opt_state, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2)), arch
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1))
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_matches_forward(arch):
    """Cached decode must agree with the un-cached forward on the same
    prefix (prefill tokens one-shot, then one decode step)."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, t = 2, 8
    batch = _smoke_batch(cfg, b=b, t=t + 1)
    batch.pop("labels")

    # full forward over t+1 tokens
    full_logits, _, _ = jax.jit(model.logits)(params, batch)

    # prefill t tokens then decode token t
    def cut(x, sl):
        return x[:, sl] if (x.ndim < 3 or x.shape[0] != 3) else x[:, :, sl]

    prefix = {
        k: (cut(v, slice(0, t)) if k != "enc_embeds" else v)
        for k, v in batch.items()
    }
    if cfg.mrope_sections:
        prefix["positions"] = batch["positions"][:, :, :t]
    _, caches = model.prefill(params, prefix, max_len=t + 4)
    last = {
        k: cut(v, slice(t, t + 1))
        for k, v in batch.items() if k != "enc_embeds"
    }
    if cfg.mrope_sections:
        last["positions"] = batch["positions"][:, :, t : t + 1]
    elif "positions" not in last:
        last["positions"] = jnp.full((b, 1), t, jnp.int32)
    dec_logits, _ = jax.jit(model.decode_step)(params, caches, last)

    a = full_logits[:, t].astype(jnp.float32)
    c = dec_logits[:, 0].astype(jnp.float32)
    # bf16 accumulation differences; compare top-1 and correlation
    assert jnp.argmax(a, -1).tolist() == jnp.argmax(c, -1).tolist(), arch
    corr = jnp.mean(
        jnp.sum(a * c, -1)
        / (jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(c, axis=-1))
    )
    assert float(corr) > 0.99, (arch, float(corr))


def test_full_configs_match_assignment_table():
    """The exact numbers from the assignment block."""
    rows = {
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }
    for arch, (l, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch


def test_long_500k_skip_rules():
    runs = {a: supports_shape(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS if a != "llama-1b"}
    assert runs["mamba2-2.7b"] and runs["zamba2-1.2b"] and runs["mixtral-8x22b"]
    for a in ["grok-1-314b", "glm4-9b", "tinyllama-1.1b", "minicpm3-4b",
              "internlm2-1.8b", "whisper-medium", "qwen2-vl-72b"]:
        assert not runs[a], a


def test_param_counts_plausible():
    """n_params() sanity vs the advertised scales."""
    expect = {
        "grok-1-314b": (250e9, 380e9),
        "mixtral-8x22b": (120e9, 180e9),
        "mamba2-2.7b": (2.0e9, 3.4e9),
        "glm4-9b": (8e9, 11e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "qwen2-vl-72b": (60e9, 80e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "llama-1b": (1.0e9, 1.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n / 1e9)
