"""Algorithm 3 (Tucker-2 conv projection) unit + integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as conv_mod
from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.accounting import optimizer_state_bytes
from repro.core.projector import ProjSpec, ProjectionRules
from repro.optim import apply_updates


def test_unfoldings_are_consistent_with_tucker_product():
    g = jax.random.normal(jax.random.key(0), (16, 12, 3, 3))
    g1 = conv_mod.mode1_canonical(g)  # (I*K1*K2, O)
    g2 = conv_mod.mode2_canonical(g)  # (O*K1*K2, I)
    assert g1.shape == (12 * 9, 16)
    assert g2.shape == (16 * 9, 12)
    # Projecting via the unfoldings == projecting via the n-mode product.
    p_o = jax.random.normal(jax.random.key(1), (16, 4))
    p_i = jax.random.normal(jax.random.key(2), (12, 5))
    core = conv_mod.project_core(g, p_o, p_i)
    # mode-1 unfolding of core must equal (g ×2 P_Iᵀ) unfolded @ P_O
    half = jnp.einsum("oikl,ib->obkl", g, p_i)
    ref = jnp.einsum("obkl,oa->abkl", half, p_o)
    np.testing.assert_allclose(core, ref, rtol=1e-5)


def test_orthonormal_full_rank_roundtrip():
    """With orthonormal square factors, project+restore is the identity."""
    g = jax.random.normal(jax.random.key(0), (8, 8, 3, 3))
    q1, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(1), (8, 8)))
    q2, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(2), (8, 8)))
    core = conv_mod.project_core(g, q1, q2)
    back = conv_mod.restore_core(core, q1, q2)
    np.testing.assert_allclose(back, g, atol=1e-5)


@pytest.mark.parametrize("name", ["coap-adamw", "galore-adamw", "8bit-coap-adamw"])
def test_conv_leaf_optimizer_runs(name):
    params = {"conv_block": {"conv_kernel": 0.01 * jnp.ones((160, 128, 3, 3))}}
    cfg = OptimizerConfig(name=name, learning_rate=1e-3, rank=None,
                          rank_ratio=4.0, t_update=2, lam=2, min_dim=64)
    tx = make_optimizer(cfg)
    state = tx.init(params)
    g = jax.tree_util.tree_map(
        lambda p: 0.1 * jax.random.normal(jax.random.key(0), p.shape), params
    )
    step = jax.jit(lambda gg, s: tx.update(gg, s, params))
    for _ in range(4):
        upd, state = step(g, state)
    u = upd["conv_block"]["conv_kernel"]
    assert u.shape == (160, 128, 3, 3)
    assert bool(jnp.all(jnp.isfinite(u)))


def test_conv_memory_reduction_vs_adam():
    """Table 1/appendix-Table-2 mechanism: Tucker-2 states ≪ dense Adam."""
    params = {"u_net": {"conv_kernel": jnp.ones((256, 256, 3, 3))}}
    dense = make_optimizer(OptimizerConfig(name="adamw", learning_rate=1e-3))
    coap = make_optimizer(
        OptimizerConfig(name="coap-adamw", learning_rate=1e-3, rank=None,
                        rank_ratio=2.0, min_dim=64)
    )
    b_dense = optimizer_state_bytes(dense.init(params)).total_bytes
    b_coap = optimizer_state_bytes(coap.init(params)).total_bytes
    # rank_o = rank_i = 256/sqrt(2)=181: core states 2*(181*181*9) + factors.
    assert b_coap < 0.75 * b_dense, (b_coap, b_dense)


def test_conv_spec_detection():
    rules = ProjectionRules(rank=64, min_dim=64)
    spec = rules.spec_for("unet/down/conv_kernel", (256, 128, 3, 3))
    assert spec.kind == "conv"
    assert spec.rank_o == 64 and spec.rank_i == 64
    # 4-D with large trailing dims = stacked matrices, NOT conv:
    spec2 = rules.spec_for("layers/w", (4, 2, 256, 512))
    assert spec2.kind == "project"
    # tiny conv falls back to dense
    spec3 = rules.spec_for("stem/conv_kernel", (32, 3, 7, 7))
    assert spec3.kind == "dense"
