"""Observability subsystem: span tracer, counter registry, measured-cost
calibration, fleet_status CLI, and the hardened liveness/metrics paths.

Five layers, matching the ``obs/`` contract:

  * **tracer** — nested spans round-trip through trace.jsonl with
    parent/depth recovered per thread, torn lines are skipped, disabled
    tracing is a shared no-op object, and the Perfetto export is a
    well-formed Chrome ``trace_event`` document;
  * **registry** — thread-safe counters/gauges, snapshot tidiness, and
    cross-process merge semantics (counters sum, gauges last-writer-win);
  * **liveness/metrics hardening** — concurrent ``beat``/``touch`` never
    publish a torn heartbeat (per-writer temp names), the registry phase
    gauge rides touches, and ``MetricsLogger.log`` fetches the whole row
    with ONE ``jax.device_get``;
  * **calibration** — ``plan.solve`` is bit-identical without an
    artifact, a ``coap-calib/v1`` artifact rescales predicted seconds
    (explicit path and ``REPRO_COAP_CALIB``), the NNLS fit recovers known
    constants, and the planned refresh schedule matches the stagger
    predicates including the step-0 whole-bucket Eqn-7 init;
  * **end-to-end** — THE acceptance scenario: a traced elastic
    kill + shrink + resume run exports a Perfetto-loadable trace with
    restore/migrate/compile/step spans per attempt, fits a calibration
    artifact the solver consumes, and ``fleet_status --json`` reports the
    same run's phase/step/staleness/counters.
"""
import json
import os
import subprocess
import sys
import threading
import time

import jax
import pytest

from repro.obs import calib as obs_calib
from repro.obs.registry import Registry, get_registry, merge_snapshots
from repro.obs.trace import (
    Tracer,
    configure,
    export_perfetto,
    get_tracer,
    read_trace,
    trace_events,
)
from repro.plan.cost import CALIB_CODEC, Calibration
from repro.plan.solver import solve
from repro.train.fault_tolerance import Heartbeat
from repro.train.metrics import MetricsLogger

_KW = dict(min_dim=8, t_update=4, lam=2, stagger_groups=2)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Tracer and registry are process-wide singletons: put them back."""
    yield
    configure(None)
    get_registry().reset()


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
def test_span_nesting_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    t = configure(path, host="h0")
    assert t.enabled
    with t.span("elastic/attempt", attempt=0):
        with t.span("loop/step", step=3) as sp:
            sp.set(late="attr")
        t.instant("supervisor/kill", reason="stale")
    with pytest.raises(RuntimeError):
        with t.span("elastic/replan"):
            raise RuntimeError("boom")

    rows = read_trace(path)
    by_name = {r["name"]: r for r in rows}
    step = by_name["loop/step"]
    assert step["parent"] == "elastic/attempt" and step["depth"] == 1
    assert step["attrs"] == {"step": 3, "late": "attr"}
    attempt = by_name["elastic/attempt"]
    assert attempt["parent"] is None and attempt["depth"] == 0
    assert attempt["host"] == "h0"
    # Child is written first (exit order) but nesting comes from fields.
    assert rows.index(step) < rows.index(attempt)
    assert attempt["dur"] >= step["dur"] >= 0
    assert by_name["supervisor/kill"]["ph"] == "i"
    assert by_name["elastic/replan"]["attrs"]["error"] == "RuntimeError"


def test_disabled_tracer_is_shared_noop():
    t = configure(None)
    assert not t.enabled
    s1 = t.span("a", x=1)
    s2 = t.span("b")
    assert s1 is s2  # one shared object: no allocation when disabled
    with s1 as sp:
        sp.set(y=2)
    t.instant("c")  # no-op, no file


def test_configure_same_path_appends(tmp_path):
    path = str(tmp_path / "t.jsonl")
    t1 = configure(path, host="h0")
    with t1.span("a"):
        pass
    t2 = configure(path, host="h0")  # worker re-boot, same journal
    assert t2 is t1
    with t2.span("b"):
        pass
    assert {r["name"] for r in read_trace(path)} == {"a", "b"}


def test_read_trace_skips_torn_lines(tmp_path):
    path = str(tmp_path / "t.jsonl")
    t = configure(path, host="h")
    with t.span("good", k=1):
        pass
    with open(path, "a") as f:
        f.write('{"name": "torn", "ts": 1.0, "dur":')  # killed mid-append
    rows = read_trace(path)
    assert [r["name"] for r in rows] == ["good"]
    assert read_trace(str(tmp_path / "absent.jsonl")) == []


def test_perfetto_export_schema(tmp_path):
    path = str(tmp_path / "t.jsonl")
    t = configure(path, host="h0")
    with t.span("loop/step", step=1):
        time.sleep(0.002)
    t.instant("supervisor/drain")
    out = str(tmp_path / "perfetto.json")
    doc = export_perfetto(path, out)
    assert json.load(open(out)) == doc
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "process_name"
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "loop/step" and x["cat"] == "loop"
    assert x["dur"] >= 2000  # µs
    assert x["args"] == {"step": 1}
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t"
    # Every event has the keys chrome://tracing requires.
    for e in evs:
        assert {"ph", "name", "pid", "tid"} <= set(e)


def test_tracer_thread_safety(tmp_path):
    path = str(tmp_path / "t.jsonl")
    t = configure(path, host="h")

    def work(i):
        for j in range(20):
            with t.span(f"thread/{i}", j=j):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    rows = read_trace(path)
    assert len(rows) == 80  # no torn/interleaved lines
    # Per-thread nesting: every span saw an empty stack (depth 0).
    assert all(r["depth"] == 0 for r in rows)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_counters_gauges_snapshot():
    r = Registry()
    r.inc("a/b")
    r.inc("a/b", 2)
    r.inc("frac", 0.5)
    r.set_phase("restore")
    r.set_gauge("g", 7)
    assert r.get("a/b") == 3.0
    assert r.get("absent") == 0.0
    assert r.gauge("phase") == "restore"
    assert r.gauge("absent", "dflt") == "dflt"
    snap = r.snapshot()
    assert snap["counters"] == {"a/b": 3, "frac": 0.5}  # int when integral
    assert isinstance(snap["counters"]["a/b"], int)
    assert snap["gauges"] == {"phase": "restore", "g": 7}
    # Snapshots are stamped for deterministic gauge merging.
    assert isinstance(snap["ts"], float) and snap["ts"] > 0
    assert snap["host"] == os.environ.get("REPRO_HOST_ID", "")
    # Snapshot is a copy, not a view.
    snap["counters"]["a/b"] = 99
    assert r.get("a/b") == 3.0
    r.reset()
    empty = r.snapshot()
    assert empty["counters"] == {} and empty["gauges"] == {}


def test_merge_snapshots():
    a = {"counters": {"x": 1, "y": 2.5}, "gauges": {"phase": "train"}}
    b = {"counters": {"x": 2}, "gauges": {"phase": "migrate"}}
    m = merge_snapshots([a, None, b])
    assert m["counters"] == {"x": 3, "y": 2.5}
    assert isinstance(m["counters"]["x"], int)
    # Unstamped snapshots keep the historical semantics: last input wins.
    assert m["gauges"]["phase"] == "migrate"
    assert merge_snapshots([]) == {"counters": {}, "gauges": {}}


def test_merge_snapshots_gauges_deterministic_by_ts():
    """Gauge merging is a function of snapshot CONTENTS, not input order:
    the newest ``(ts, host)`` stamp wins even when the caller (e.g.
    ``fleet_status`` globbing heartbeat files) iterates oldest-last or in
    filesystem order."""
    new = {"gauges": {"phase": "train"}, "ts": 200.0, "host": "h1"}
    old = {"gauges": {"phase": "boot"}, "ts": 100.0, "host": "h9"}
    for order in ([old, new], [new, old]):
        assert merge_snapshots(order)["gauges"]["phase"] == "train"
    # Wall-clock tie → host id breaks it, still order independent.
    a = {"gauges": {"g": "a"}, "ts": 50.0, "host": "hostA"}
    b = {"gauges": {"g": "b"}, "ts": 50.0, "host": "hostB"}
    for order in ([a, b], [b, a]):
        assert merge_snapshots(order)["gauges"]["g"] == "b"
    # Stamped beats unstamped regardless of position.
    stamped = {"gauges": {"g": "s"}, "ts": 1.0, "host": ""}
    unstamped = {"gauges": {"g": "u"}}
    for order in ([stamped, unstamped], [unstamped, stamped]):
        assert merge_snapshots(order)["gauges"]["g"] == "s"


def test_merge_snapshots_counter_properties():
    """Counter merging is associative and commutative (property test over
    the deterministic hypothesis shim): any merge tree over any
    permutation yields the same counter totals."""
    from hypothesis import given, settings
    from hypothesis import strategies as st

    keys = st.sampled_from(["a", "b", "c/d"])
    counters = st.dictionaries(keys, st.integers(0, 100), max_size=3)
    snap = counters.map(lambda c: {"counters": dict(c), "gauges": {}})

    @settings(max_examples=25, deadline=None)
    @given(snap, snap, snap)
    def check(x, y, z):
        left = merge_snapshots([merge_snapshots([x, y]), z])
        right = merge_snapshots([x, merge_snapshots([y, z])])
        flat = merge_snapshots([x, y, z])
        swapped = merge_snapshots([z, x, y])
        assert left["counters"] == right["counters"] == flat["counters"]
        assert swapped["counters"] == flat["counters"]

    check()


def test_registry_merge_across_processes(tmp_path):
    """A worker process's snapshot (as it rides in heartbeats) merges by
    summation with the local one."""
    code = (
        "import json, sys\n"
        "from repro.obs.registry import get_registry\n"
        "r = get_registry(); r.inc('ckpt/save', 4); r.set_phase('train')\n"
        "json.dump(r.snapshot(), sys.stdout)\n"
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), check=True,
    )
    remote = json.loads(out.stdout)
    local = Registry()
    local.inc("ckpt/save")
    m = merge_snapshots([local.snapshot(), remote])
    assert m["counters"]["ckpt/save"] == 5
    assert m["gauges"]["phase"] == "train"


# ---------------------------------------------------------------------------
# Liveness / metrics hardening
# ---------------------------------------------------------------------------
def test_heartbeat_never_torn_under_concurrent_writers(tmp_path):
    """``beat`` (loop thread) and ``touch`` (refresher thread) race on one
    path: per-writer temp names mean a reader NEVER sees a torn file —
    which is exactly what keeps a live worker from being killed."""
    hb = Heartbeat(str(tmp_path / "heartbeat.json"), timeout=60.0)
    hb.beat(0)
    stop = threading.Event()
    errors = []

    def beater():
        i = 0
        while not stop.is_set():
            hb.beat(i, extra={"counters": {"loop/step": i}})
            i += 1

    def toucher():
        while not stop.is_set():
            hb.touch()

    def reader():
        while not stop.is_set():
            payload = hb.read()
            if payload is None:  # torn or vanished — the lethal case
                errors.append("torn/missing heartbeat observed")
            elif hb.status() not in ("alive",):
                errors.append(f"status {hb.status()}")

    threads = [threading.Thread(target=f)
               for f in (beater, toucher, reader, reader)]
    for t in threads:
        t.start()
    time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
    assert hb.status() == "alive"
    # No temp droppings left behind.
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_heartbeat_touch_carries_phase(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    get_registry().set_phase("migrate")
    hb.touch()
    assert hb.read()["phase"] == "migrate"
    assert hb.read()["step"] == 0  # touch never claims progress


def test_metrics_logger_one_device_get(tmp_path, monkeypatch):
    import repro.train.metrics as metrics_mod

    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(x)
        return real(x)

    monkeypatch.setattr(metrics_mod.jax, "device_get", counting)
    path = str(tmp_path / "metrics.jsonl")
    reg = get_registry()
    reg.inc("ckpt/save", 2)
    with MetricsLogger(path) as lg:
        row = lg.log(0, {"loss": jax.numpy.float32(1.5),
                         "ceu": jax.numpy.float32(2.0)}, tokens=64)
        assert row["loss"] == 1.5
        assert len(calls) == 1  # ONE transfer for the whole row
        # Counter deltas ride the row from the host-side registry without
        # a second device transfer.
        assert row["delta/ckpt/save"] == 2
        reg.inc("ckpt/save")
        row1 = lg.log(1, {"loss": jax.numpy.float32(1.2),
                          "ceu": jax.numpy.float32(2.1)}, tokens=64)
        assert len(calls) == 2  # still one device_get PER ROW
        assert row1["delta/ckpt/save"] == 1
        row2 = lg.log(2, {"loss": jax.numpy.float32(1.1),
                          "ceu": jax.numpy.float32(2.2)}, tokens=64)
        # Unchanged counters emit no delta keys (rows stay tidy).
        assert "delta/ckpt/save" not in row2
        assert len(calls) == 3
    assert lg._f is None  # context manager closed the handle
    rows = [json.loads(line) for line in open(path)]
    assert [r["step"] for r in rows] == [0, 1, 2]
    assert rows[1]["tokens_per_s"] > 0


# ---------------------------------------------------------------------------
# fleet_status
# ---------------------------------------------------------------------------
def _mk_run_dir(tmp_path, name, hb=None, spec=None, events=(), metrics=(),
                done=None, torn_tail=False):
    d = tmp_path / name
    d.mkdir()
    if spec is not None:
        (d / "worker_spec.json").write_text(json.dumps(spec))
    if hb is not None:
        (d / "heartbeat.json").write_text(json.dumps(hb))
    if events or torn_tail:
        with open(d / "events.jsonl", "w") as f:
            for e in events:
                f.write(json.dumps(e) + "\n")
            if torn_tail:
                f.write('{"time": 1.0, "host": "x", "event"')
    if metrics:
        with open(d / "metrics.jsonl", "w") as f:
            for m in metrics:
                f.write(json.dumps(m) + "\n")
    if done is not None:
        (d / "DONE.json").write_text(json.dumps(done))
    return str(d)


def test_fleet_status_json_on_synthetic_journals(tmp_path, capsys):
    from repro.launch import fleet_status as fs

    now = time.time()
    alive = _mk_run_dir(
        tmp_path, "alive",
        hb={"step": 7, "time": now, "phase": "train",
            "straggler_flagged": 1, "counters": {"ckpt/save": 3}},
        spec={"elastic": {"host_id": "host-a", "total_steps": 20,
                          "heartbeat_timeout_s": 300.0}},
        events=[{"time": now - 1, "host": "host-a",
                 "event": ["resume", 0, None, 8]}],
        metrics=[{"step": 7, "loss": 2.25}],
        torn_tail=True,
    )
    # Checkpoints: only dirs with a manifest count.
    os.makedirs(os.path.join(alive, "ckpt_00000004"))
    open(os.path.join(alive, "ckpt_00000004", "manifest.json"), "w").write(
        "{}"
    )
    os.makedirs(os.path.join(alive, "ckpt_00000006"))  # torn: no manifest

    stale = _mk_run_dir(
        tmp_path, "stale",
        hb={"step": 3, "time": now - 10_000, "phase": "train"},
    )
    dead = _mk_run_dir(tmp_path, "dead")  # no heartbeat at all
    done = _mk_run_dir(
        tmp_path, "done",
        hb={"step": 20, "time": now - 10_000},
        done={"step": 20, "loss": 1.5, "attempt": 2},
    )

    rc = fs.main(["--dir", alive, "--dir", stale, "--dir", dead,
                  "--dir", done, "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    hosts = {h["host"]: h for h in doc["hosts"]}

    a = hosts["host-a"]  # named by worker_spec.json, not the dir
    assert a["status"] == "alive"
    assert a["step"] == 7 and a["total_steps"] == 20
    assert a["phase"] == "train"
    assert a["staleness_s"] < 60
    assert a["counters"] == {"ckpt/save": 3}
    assert a["ckpt_latest"] == 4 and a["ckpt_count"] == 1
    assert a["last_metrics"]["loss"] == 2.25
    assert a["recent_events"][-1]["event"] == ["resume", 0, None, 8]

    assert hosts["stale"]["status"] == "stale"
    assert hosts["stale"]["staleness_s"] > hosts["stale"][
        "heartbeat_timeout_s"]
    assert hosts["dead"]["status"] == "missing"
    assert hosts["dead"]["step"] is None
    assert hosts["done"]["status"] == "done"  # DONE trumps stale heartbeat
    assert hosts["done"]["step"] == 20

    # Human rendering of the same doc holds every host row.
    table = fs.render(doc)
    for name in ("host-a", "stale", "dead", "done"):
        assert name in table


def test_fleet_status_consensus_view(tmp_path, capsys):
    from repro.launch import fleet_status as fs
    from repro.train.fleet import FleetConfig, PlanConsensus, plan_digest

    fleet_dir = str(tmp_path / "fleet")
    plan = {"codec": "coap-plan/v1", "buckets": [1, 2]}
    a = PlanConsensus(FleetConfig(fleet_dir=fleet_dir, host_id="a"))
    b = PlanConsensus(FleetConfig(fleet_dir=fleet_dir, host_id="b"))
    a.beat()
    b.beat()
    got, role = a.plan_for_epoch("6:4x1024", lambda: plan)
    assert got == plan and role == "published"

    rc = fs.main(["--fleet-dir", fleet_dir, "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    fleet = doc["fleet"]
    assert fleet["n_alive"] == 2
    assert sorted(m["host"] for m in fleet["members"]) == ["a", "b"]
    cur = fleet["current_epoch"]
    assert cur["epoch"] == "6_4x1024"  # slugged
    assert cur["plan_digest"] == plan_digest(plan)
    assert cur["committed_by"] == "a"
    assert "digest " + plan_digest(plan)[:12] in fs.render(doc)


def test_fleet_status_requires_a_target():
    from repro.launch import fleet_status as fs

    with pytest.raises(SystemExit):
        fs.main(["--json"])


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------
def _toy_params():
    key = jax.random.key(3)
    mk = lambda i, shp: 0.3 * jax.random.normal(
        jax.random.fold_in(key, i), shp
    )
    return {"w1": mk(0, (64, 32)), "w2": mk(1, (64, 32)), "b": mk(2, (64,))}


def test_solver_bit_identical_without_artifact(tmp_path, monkeypatch):
    """No calibration artifact -> plans are bit-identical to an explicit
    analytic Calibration (the parity acceptance criterion). Pointing
    REPRO_COAP_CALIB at a nonexistent file pins the no-artifact path
    regardless of what lives under the repo's artifacts/."""
    monkeypatch.setenv("REPRO_COAP_CALIB", str(tmp_path / "absent.json"))
    params = _toy_params()
    p1 = solve(params, 10**12, **_KW)
    p2 = solve(params, 10**12, calib=Calibration.load(), **_KW)
    assert json.dumps(p1.to_dict(), sort_keys=True) == json.dumps(
        p2.to_dict(), sort_keys=True
    )
    assert p1.cost["calibration"]["hbm_bw"] == pytest.approx(819e9)


def test_calib_artifact_rescales_cost(tmp_path, monkeypatch):
    params = _toy_params()
    base = solve(params, 10**12, **_KW)
    art = str(tmp_path / "coap-calib.json")
    json.dump(
        {"codec": CALIB_CODEC, "hbm_bw": 819e9 / 4, "peak_flops": 197e12 / 4},
        open(art, "w"),
    )
    # Explicit path.
    c = Calibration.load(calib_path=art)
    assert c.hbm_bw == pytest.approx(819e9 / 4)
    assert ("hbm_bw", "coap-calib.json") in [tuple(s) for s in c.sources]
    slow = solve(params, 10**12, calib=c, **_KW)
    assert slow.cost["step_seconds"] == pytest.approx(
        4 * base.cost["step_seconds"]
    )
    # Env var consumption (what a traced run's artifact uses).
    monkeypatch.setenv("REPRO_COAP_CALIB", art)
    c_env = Calibration.load()
    assert c_env.hbm_bw == pytest.approx(819e9 / 4)


def test_calib_artifact_wrong_codec_ignored_and_loud(tmp_path):
    art = str(tmp_path / "bad.json")
    json.dump({"codec": "coap-calib/v999", "hbm_bw": 1.0}, open(art, "w"))
    c = Calibration.load(calib_path=art)  # silently-optional consumer
    assert c.hbm_bw == pytest.approx(819e9)  # analytic constant kept
    with pytest.raises(ValueError, match="coap-calib/v1"):
        obs_calib.load_calib(art)  # loud reader


def test_fit_nnls_recovers_constants():
    x_true, y_true = 1.0 / 800e9, 1.0 / 200e12
    samples = [
        {"bytes": b, "flops": f, "t": x_true * b + y_true * f}
        for b, f in [(1e9, 1e12), (2e9, 1e12), (1e9, 8e12), (4e9, 2e12)]
    ]
    x, y, res = obs_calib._fit_nnls_2(samples)
    assert x == pytest.approx(x_true, rel=1e-6)
    assert y == pytest.approx(y_true, rel=1e-6)
    assert res < 1e-12
    # Degenerate population (flops never varies the time): the fit falls
    # back to the better single-variable model, never negative.
    flat = [{"bytes": b, "flops": 0.0, "t": x_true * b}
            for b in (1e9, 2e9, 3e9)]
    x2, y2, _ = obs_calib._fit_nnls_2(flat)
    assert x2 == pytest.approx(x_true, rel=1e-6) and y2 == 0.0


def test_planned_refresh_schedule_matches_predicates():
    from repro.core.api import OptimizerConfig

    params = _toy_params()
    plan = solve(params, 10**12, **_KW)
    ocfg = OptimizerConfig(name="coap-adamw", learning_rate=1e-3)
    sched = obs_calib.planned_refresh_schedule(plan, params, ocfg)

    # Step 0: the mandatory whole-bucket Eqn-7 init, one event per bucket.
    ev0 = sched(0)
    assert ev0 and all(e["kind"] == "recal" and e["frac"] == 1.0
                       for e in ev0)
    t_u, lam = _KW["t_update"], _KW["lam"]
    seen_eqn6 = seen_recal = False
    for step in range(1, 2 * lam * t_u + 1):
        for e in sched(step):
            # Group refreshes exactly when its stagger predicate fires.
            assert (step + e["phase"]) % t_u == 0
            if (step + e["phase"]) % (lam * t_u) == 0:
                assert e["kind"] == "recal"
                seen_recal = True
            else:
                assert e["kind"] == "eqn6"
                seen_eqn6 = True
            assert 0 < e["frac"] <= 1.0
    assert seen_eqn6 and seen_recal


def test_build_from_trace_requires_samples(tmp_path):
    path = str(tmp_path / "t.jsonl")
    t = configure(path, host="h")
    with t.span("loop/step", step=0, compile=True):  # excluded from fit
        pass
    plan = solve(_toy_params(), 10**12, **_KW)
    with pytest.raises(ValueError, match="usable loop/step"):
        obs_calib.build_from_trace(path, plan, min_samples=4)


# ---------------------------------------------------------------------------
# End-to-end: traced elastic run -> Perfetto + calib + fleet_status
# ---------------------------------------------------------------------------
def test_traced_kill_shrink_resume_end_to_end(tmp_path, capsys):
    """THE acceptance scenario, traced: seeded kill at step 7 + topology
    shrink 8->4 at step 6 under a recording tracer. The trace must carry
    replan/restore/migrate/compile/step spans per attempt, export to a
    loadable Perfetto document, fit a coap-calib/v1 artifact the solver
    consumes via REPRO_COAP_CALIB, and fleet_status must report the run
    from the same directory."""
    from repro.configs import get_smoke
    from repro.data.synthetic import SyntheticLM
    from repro.launch import fleet_status as fs
    from repro.models.model import build_model
    from repro.train.elastic import (
        ElasticConfig,
        ElasticSupervisor,
        Topology,
    )
    from repro.train.faults import FaultInjector, FaultSchedule

    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
    batch_fn = lambda step, host: data.batch(step, batch=4, seq=16,
                                             host=host)
    params = model.abstract_params()
    kw = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)
    from repro.plan.solver import solve_for_topology

    h32 = solve_for_topology(params, 1, 10**12, quantize="off",
                             **kw).predicted["hbm_total_bytes"]
    h8 = solve_for_topology(params, 1, 10**12, quantize="force",
                            **kw).predicted["hbm_total_bytes"]
    per_dev = (h32 + h8) // 2 // 4

    run_dir = tmp_path / "run"
    run_dir.mkdir()
    trace_path = str(run_dir / "trace.jsonl")
    ecfg = ElasticConfig(
        ckpt_dir=str(run_dir),
        total_steps=12,
        topology=(Topology(8, per_dev), Topology(4, per_dev, from_step=6)),
        solve_kw=kw,
        ckpt_every=2,
        log_every=2,
        backoff_base=0.0,
        heartbeat_path=str(run_dir / "heartbeat.json"),
        metrics_path=str(run_dir / "metrics.jsonl"),
        events_path=str(run_dir / "events.jsonl"),
        trace_path=trace_path,
        host_id="host-e2e",
    )
    from repro.core.api import OptimizerConfig

    inj = FaultInjector(FaultSchedule(kill_at=(7,)), seed=0)
    sup = ElasticSupervisor(
        model, batch_fn, ecfg,
        ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
        fault_injector=inj,
    )
    state = sup.run()
    assert int(state.step) == 12
    assert [e[0] for e in sup.events] == ["resume", "crash", "migrate",
                                          "resume"]

    # -- the trace carries the full lifecycle --------------------------------
    rows = read_trace(trace_path)
    names = [r["name"] for r in rows]
    for required in ("elastic/attempt", "elastic/replan", "elastic/restore",
                     "elastic/migrate", "loop/step", "loop/checkpoint"):
        assert required in names, f"missing span {required}"
    steps = [r for r in rows if r["name"] == "loop/step"]
    # Two attempts -> two compile-tagged first steps. Attempt 1 ran steps
    # 0..6 (killed entering 7), attempt 2 resumed the step-6 checkpoint
    # and ran 6..11.
    compiles = [r for r in steps if (r.get("attrs") or {}).get("compile")]
    assert len(compiles) == 2
    assert sorted(r["attrs"]["step"] for r in steps) == sorted(
        list(range(7)) + list(range(6, 12))
    )
    # Refresh attribution present: step 0 carries the whole-bucket init.
    s0 = next(r for r in steps if r["attrs"]["step"] == 0)
    assert s0["attrs"]["refresh"][0]["frac"] == 1.0
    resumes = [r for r in rows if r["name"] == "elastic/resume"]
    assert [(r["attrs"]["attempt"], r["attrs"]["n_devices"])
            for r in resumes] == [(0, 8), (1, 4)]

    # -- Perfetto export -----------------------------------------------------
    doc = export_perfetto(trace_path, str(run_dir / "perfetto.json"))
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {"elastic/migrate", "loop/step"} <= {e["name"] for e in xs}
    assert all("dur" in e and e["ts"] > 0 for e in xs)

    # -- fit + consume the calibration artifact ------------------------------
    plan4 = sup.plan_for(Topology(4, per_dev, from_step=6))
    art_path = str(run_dir / "coap-calib.json")
    artifact = obs_calib.build_from_trace(trace_path, plan4,
                                          out_path=art_path)
    assert artifact["codec"] == CALIB_CODEC
    assert artifact["n_samples"] >= 10  # 13 step spans minus 2 compiles
    assert artifact["n_refresh_samples"] >= 1
    assert artifact["hbm_bw"] or artifact["peak_flops"]
    os.environ["REPRO_COAP_CALIB"] = art_path
    try:
        calibrated = Calibration.load()
        fitted = solve(params, 10**12, calib=calibrated, **kw)
    finally:
        del os.environ["REPRO_COAP_CALIB"]
    assert any("coap-calib.json" in s[1]
               for s in fitted.cost["calibration_sources"])
    assert fitted.cost["step_seconds"] > 0

    # -- fleet_status over the same directory --------------------------------
    rc = fs.main(["--dir", str(run_dir), "--json"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    h = out["hosts"][0]
    assert h["status"] == "alive"  # heartbeat still fresh
    assert h["step"] == 11  # last in-loop beat (final ckpt comes after)
    assert h["phase"] == "train"
    assert h["counters"]["ckpt/save"] >= 1
    assert h["ckpt_latest"] == 12
    assert h["last_metrics"]["loss"] > 0
    kinds = [e["event"][0] for e in h["recent_events"]]
    assert "migrate" in kinds and "resume" in kinds
