"""Shape-bucketed leaf batching + fused-path routing.

Asserts the dispatch-count contract of ``scale_by_projected_adam``:
congruent ``(shape, spec, dtype)`` projected leaves are stacked and updated
by ONE (vmapped) fused-kernel launch per bucket; with ``quantize=True`` the
step routes through the single-pass int8 kernel with no fp32 M/V in the
optimizer state; and bucketed vs per-leaf execution is bit-identical.

Launch counting: ``update_fn`` invokes ``kops.coap_fused_update_bp`` /
``coap_fused_update_q8`` once per bucket at trace time, and each invocation
is exactly one kernel dispatch per step at run time (a vmapped pallas_call
is still a single launch). Counting calls during a single jit trace
therefore counts per-step launches — and re-stepping a cached jit must add
zero traces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.coap_adam import (
    ProjectedAdamConfig,
    ProjLeaf,
    scale_by_projected_adam,
)
from repro.core.projector import ProjectionRules
from repro.kernels import ops as kops


def _congruent_params(n_leaves=8, shape=(96, 64), odd=True):
    params = {f"blk{i}": {"w": jnp.zeros(shape)} for i in range(n_leaves)}
    if odd:
        params["odd"] = {"w": jnp.zeros((128, 48))}  # its own bucket
        params["tiny_bias"] = jnp.zeros((7,))  # dense leaf
    return params


def _cfg(**kw):
    kw.setdefault("rules", ProjectionRules(rank=16, min_dim=8))
    return ProjectedAdamConfig(**kw)


def _grads(params, seed=0):
    """Distinct gradient per leaf (folds the flat leaf index, NOT a shape
    property — congruent bucket members must differ so ordering bugs in the
    stack/scatter round-trip can't hide)."""
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )


def _count_calls(monkeypatch, name):
    calls = []
    orig = getattr(kops, name)

    def counting(*a, **k):
        calls.append(name)
        return orig(*a, **k)

    monkeypatch.setattr(kops, name, counting)
    return calls


def test_one_launch_per_projected_bucket_fp32(monkeypatch):
    """8 congruent + 1 odd projected leaf -> exactly 2 fused launches."""
    params = _congruent_params(8)
    tx = scale_by_projected_adam(_cfg())
    state = tx.init(params)
    g = _grads(params)
    calls = _count_calls(monkeypatch, "coap_fused_update_bp")
    step = jax.jit(lambda gg, s: tx.update(gg, s, None))
    upd, state = step(g, state)
    assert calls.count("coap_fused_update_bp") == 2, calls
    # re-stepping the cached jit must not retrace (no extra launches traced)
    upd, state = step(g, state)
    assert calls.count("coap_fused_update_bp") == 2, calls
    for leaf in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_one_launch_per_projected_bucket_q8(monkeypatch):
    """quantize=True: one single-pass int8 launch per congruent bucket."""
    params = _congruent_params(8)
    tx = scale_by_projected_adam(_cfg(quantize=True))
    state = tx.init(params)
    g = _grads(params)
    calls = _count_calls(monkeypatch, "coap_fused_update_q8")
    step = jax.jit(lambda gg, s: tx.update(gg, s, None))
    upd, state = step(g, state)
    assert calls.count("coap_fused_update_q8") == 2, calls


def test_q8_state_holds_no_fp32_moments():
    """With quantize=True every projected moment lives as int8 (row-block
    codec) — no fp32 M/V is ever part of the optimizer state."""
    params = _congruent_params(4)
    tx = scale_by_projected_adam(_cfg(quantize=True))
    state = tx.init(params)
    g = _grads(params)
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, state)
    leaves = [
        x for x in jax.tree_util.tree_leaves(
            state.leaves, is_leaf=lambda x: isinstance(x, ProjLeaf)
        )
        if isinstance(x, ProjLeaf)
    ]
    assert leaves, "no projected leaves found"
    for leaf in leaves:
        assert leaf.m.dtype == jnp.int8 and leaf.v.dtype == jnp.int8
        assert leaf.m.shape == leaf.v.shape  # shape-preserving codec
        assert leaf.m_scale.shape == leaf.m.shape[:-1] + (
            leaf.m_scale.shape[-1],
        )


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("strategy", ["coap", "galore", "flora"])
def test_bucketed_matches_per_leaf(quantize, strategy):
    """bucket_leaves=True/False must agree: all update paths broadcast over
    the stack axis and flora's RNG folds the original flat leaf index.
    int8 states must match bit-for-bit; float leaves to XLA-dot ulp noise
    (stacking changes the backend's accumulation tree)."""
    params = _congruent_params(4)
    g = _grads(params, seed=3)
    outs = {}
    for bucketed in (True, False):
        tx = scale_by_projected_adam(
            _cfg(strategy=strategy, quantize=quantize, t_update=2,
                 bucket_leaves=bucketed)
        )
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(3):
            upd, state = step(g, state)
        outs[bucketed] = (upd, state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


def test_q8_fused_bytes_accessed_ratio_llama1b():
    """Acceptance gate: on LLaMA-1B shapes the fused int8 step must show
    >=1.5x lower bytes-accessed than the unfused quantized schedule (it
    clears the bar under BOTH accountings — dispatch cost_analysis and the
    conservative variant that charges the kernel its internal P re-stream).
    """
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.overhead import LLAMA1B_MATS, quantized_fused_vs_unfused

    rows = quantized_fused_vs_unfused(LLAMA1B_MATS, rank=512)
    assert len(rows) == 3
    for label, row in rows.items():
        assert row["ratio"] >= 1.5, (label, row["ratio"])
        assert row["ratio_conservative"] >= 1.5, (
            label, row["ratio_conservative"]
        )
        assert row["launches_unfused"] == 8 and row["launches_fused"] == 1


def test_compressed_update_accepts_quantized_states():
    """compressed_update now runs the dequant→reduce→requant schedule for
    int8 states (the former NotImplementedError): on a 1-pod mesh (pmean is
    the identity) the quantized compressed step must run end-to-end and
    emit int8 codes + finite updates. Multi-pod numerical parity lives in
    tests/test_distributed.py."""
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.distributed.compression import compressed_update

    cfg = _cfg(quantize=True, use_fused_kernel=False, t_update=2, lam=2)
    params = {"w": jnp.zeros((96, 64)), "bias": jnp.zeros((7,))}
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    g = _grads(params)
    mesh = jax.make_mesh((1,), ("pod",))

    def body(gg, st):
        return compressed_update(cfg, gg, st, "pod")

    mapped = compat.shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False, axis_names={"pod"},
    )
    for _ in range(3):
        upd, state = jax.jit(mapped)(g, state)
    assert state.leaves["w"].m.dtype == jnp.int8
    for leaf in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_mixed_tree_full_optimizer_runs():
    """End-to-end through the public factory: congruent layers + embeddings
    + conv + bias in one tree, quantized, several steps, finite updates."""
    params = {
        "layers": {f"l{i}": {"w": jnp.zeros((160, 96))} for i in range(5)},
        "embed": {"embedding": 0.02 * jnp.ones((256, 96))},
        "conv_block": {"conv_kernel": 0.01 * jnp.ones((128, 128, 3, 3))},
        "head_bias": jnp.zeros((96,)),
    }
    cfg = OptimizerConfig(name="8bit-coap-adamw", learning_rate=1e-3,
                          rank=32, min_dim=64, t_update=2, lam=2)
    tx = make_optimizer(cfg)
    state = tx.init(params)
    g = _grads(params, seed=11)
    step = jax.jit(lambda gg, s: tx.update(gg, s, params))
    for _ in range(4):
        upd, state = step(g, state)
    for leaf in jax.tree_util.tree_leaves(upd):
        assert bool(jnp.all(jnp.isfinite(leaf)))
