"""Preemption-native elastic training: plan migration parity + the
replan → migrate → resume control loop under fault injection.

Two layers, matching ``train/elastic.py``'s contract:

  * **migration parity** — ``stacked_state.migrate`` on real planned
    optimizer states: rank truncation keeps leading columns bit-exact,
    Eqn-7-style expansion keeps old columns and zeros new moment columns,
    quantize flips round-trip within one codec rounding, and every
    migrated state's bytes match ``accounting.abstract_state_bytes`` of
    the TARGET optimizer exactly, category by category;
  * **control loop** — a seeded fault schedule (kill at step k, topology
    shrink 8→4 with a fresh plan) resumes through ``ElasticSupervisor``
    to a final loss within tolerance of the uninterrupted baseline, with
    stagger phases re-derived bit-identically across two resumes from
    the same checkpoint, torn checkpoints skipped newest→oldest, and the
    crash budget propagating the last failure when exhausted.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import accounting, stacked_state as ss
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.plan import apply as plan_apply
from repro.plan.solver import solve, solve_for_topology
from repro.train import checkpoint as ckpt
from repro.train.elastic import (
    ElasticConfig,
    ElasticSupervisor,
    Topology,
    find_projected_state,
    migrate_opt_state,
    stagger_signature,
    topology_at,
)
from repro.train.fault_tolerance import (
    CrashBudget,
    Heartbeat,
    StragglerDetector,
    backoff_delay,
    run_with_restart,
)
from repro.train.faults import FaultInjector, FaultSchedule, InjectedKill

_KW = dict(min_dim=8, t_update=4, lam=2, stagger_groups=2)


# ---------------------------------------------------------------------------
# Migration parity (stacked_state.migrate on real planned states)
# ---------------------------------------------------------------------------
def _params():
    key = jax.random.key(7)
    mk = lambda i, shp: 0.3 * jax.random.normal(jax.random.fold_in(key, i), shp)
    return {
        "w1": mk(0, (64, 32)),
        "w2": mk(1, (64, 32)),
        "conv": mk(2, (16, 12, 3, 3)),
        "b": mk(3, (64,)),
    }


def _planned_state(params, plan, steps=3):
    """A real optimizer state (the raw chain state, not a TrainState)
    under ``plan`` with populated moments."""
    ocfg = OptimizerConfig(name="coap-adamw", learning_rate=1e-3, plan=plan)
    tx = make_optimizer(ocfg)
    state = tx.init(params)
    key = jax.random.key(11)
    for i in range(steps):
        g = jax.tree_util.tree_map(
            lambda p: 0.1 * jax.random.normal(
                jax.random.fold_in(key, i), p.shape
            ),
            params,
        )
        _, state = jax.jit(lambda gg, s: tx.update(gg, s, params))(g, state)
    return ocfg, tx, state


def _by_path(leaves: ss.StackedLeaves):
    """Logical path -> (per-leaf state, spec) for every bucketed leaf."""
    flat = ss.decode(leaves)
    out = {}
    for info in leaves.layout.buckets:
        for idx, path in zip(info.indices, info.paths):
            out[path] = (flat[idx], info.spec)
    return out


def _assert_bytes_match_target(migrated_opt_state, dst_plan, params):
    """Migrated bytes == the TARGET optimizer's abstract accounting,
    category by category (the planner's exactness contract, preserved
    through migration)."""
    dst_tx = make_optimizer(
        OptimizerConfig(name="coap-adamw", learning_rate=1e-3, plan=dst_plan)
    )
    want = accounting.abstract_state_bytes(
        dst_tx, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
    )
    got = accounting.optimizer_state_bytes(migrated_opt_state)
    assert got.by_category == want.by_category


@pytest.fixture(scope="module")
def plans():
    params = _params()
    p_fp32 = solve(params, 10**12, quantize="off", **_KW)
    p_int8 = solve(params, 10**12, quantize="force", **_KW)
    p_lowrank = solve(params, 10**12, quantize="off",
                      rank_compression=8.0, **_KW)
    return params, p_fp32, p_int8, p_lowrank


def test_migrate_same_plan_is_bit_exact(plans):
    """Same plan, same codec: pass-through — int8 codes included."""
    params, _, p_int8, _ = plans
    ocfg, _, opt = _planned_state(params, p_int8)
    migrated = migrate_opt_state(
        opt, p_int8, p_int8, params, ocfg
    )
    src = find_projected_state(opt)
    dst = find_projected_state(migrated)
    for a, b in zip(jax.tree_util.tree_leaves(src.leaves),
                    jax.tree_util.tree_leaves(dst.leaves)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_bytes_match_target(migrated, p_int8, params)


def test_migrate_rank_truncation_keeps_leading_columns(plans):
    """fp32 full-rank -> fp32 low-rank: P and the moments keep their
    leading columns bit-exactly (truncation loses only the dropped
    columns); conv factors truncate on both Tucker-2 axes."""
    params, p_fp32, _, p_lowrank = plans
    ocfg, _, opt = _planned_state(params, p_fp32)
    migrated = migrate_opt_state(
        opt, p_fp32, p_lowrank, params, ocfg
    )
    src = _by_path(find_projected_state(opt).leaves)
    dst = _by_path(find_projected_state(migrated).leaves)
    assert set(src) == set(dst)
    checked = 0
    for path, (d, dspec) in dst.items():
        s, sspec = src[path]
        if hasattr(d, "p"):  # projected leaf
            r = d.p.shape[-1]
            assert r < s.p.shape[-1]
            np.testing.assert_array_equal(np.asarray(d.p),
                                          np.asarray(s.p[..., :r]))
            np.testing.assert_array_equal(np.asarray(d.m),
                                          np.asarray(s.m[..., :r]))
            np.testing.assert_array_equal(np.asarray(d.v),
                                          np.asarray(s.v[..., :r]))
            checked += 1
        elif hasattr(d, "p_o"):  # conv leaf
            ro, ri = d.p_o.shape[-1], d.p_i.shape[-1]
            assert ro < s.p_o.shape[-1] and ri < s.p_i.shape[-1]
            np.testing.assert_array_equal(np.asarray(d.p_o),
                                          np.asarray(s.p_o[..., :ro]))
            np.testing.assert_array_equal(np.asarray(d.p_i),
                                          np.asarray(s.p_i[..., :ri]))
            np.testing.assert_array_equal(np.asarray(d.m),
                                          np.asarray(s.m[:ro, :ri]))
            checked += 1
    assert checked >= 2  # at least one projected and the conv bucket
    count_src = find_projected_state(opt).count
    assert int(find_projected_state(migrated).count) == int(count_src)
    _assert_bytes_match_target(migrated, p_lowrank, params)


def test_migrate_rank_expansion_preserves_and_orthogonalizes(plans):
    """Low-rank -> full-rank: old P columns bit-exact, new P columns
    non-degenerate and orthogonal to the span of the old ones (the
    Eqn-7-style re-expansion), new MOMENT columns exactly zero."""
    params, p_fp32, _, p_lowrank = plans
    ocfg, _, opt = _planned_state(params, p_lowrank)
    migrated = migrate_opt_state(
        opt, p_lowrank, p_fp32, params, ocfg
    )
    src = _by_path(find_projected_state(opt).leaves)
    dst = _by_path(find_projected_state(migrated).leaves)
    for path, (d, _) in dst.items():
        if not hasattr(d, "p"):
            continue
        s, _ = src[path]
        r_old, r_new = s.p.shape[-1], d.p.shape[-1]
        assert r_new > r_old
        np.testing.assert_array_equal(np.asarray(d.p[..., :r_old]),
                                      np.asarray(s.p))
        new_p = np.asarray(d.p[..., r_old:], dtype=np.float64)
        old_p = np.asarray(s.p, dtype=np.float64)
        # non-degenerate and orthogonal to span(old columns)
        assert np.all(np.linalg.norm(new_p, axis=-2) > 1e-6)
        q, _ = np.linalg.qr(old_p)
        leak = np.abs(q.T @ new_p).max()
        assert leak < 1e-4
        np.testing.assert_array_equal(
            np.asarray(d.m[..., r_old:]),
            np.zeros_like(np.asarray(d.m[..., r_old:])),
        )
        np.testing.assert_array_equal(np.asarray(d.m[..., :r_old]),
                                      np.asarray(s.m))
    _assert_bytes_match_target(migrated, p_fp32, params)


def test_migrate_quantize_flip_roundtrip(plans):
    """fp32 -> int8 -> fp32 costs exactly one blockwise-codec rounding:
    the round-tripped moments match the originals within the int8 step
    size, and both directions' bytes match the target accounting."""
    params, p_fp32, p_int8, _ = plans
    ocfg, _, opt = _planned_state(params, p_fp32)
    to_q = migrate_opt_state(opt, p_fp32, p_int8, params, ocfg)
    _assert_bytes_match_target(to_q, p_int8, params)
    back = migrate_opt_state(to_q, p_int8, p_fp32, params, ocfg)
    _assert_bytes_match_target(back, p_fp32, params)

    src = _by_path(find_projected_state(opt).leaves)
    rt = _by_path(find_projected_state(back).leaves)
    for path, (d, _) in rt.items():
        s, _ = src[path]
        for field in ("m", "v"):
            if not hasattr(s, field):
                continue
            a = np.asarray(getattr(s, field))
            b = np.asarray(getattr(d, field))
            assert a.dtype == b.dtype
            tol = np.abs(a).max() / 127.0 + 1e-12
            np.testing.assert_allclose(b, a, atol=tol)


def test_migrate_structure_mismatch_raises(plans):
    """A target layout over DIFFERENT leaves (renamed/added params) is a
    structure change, not a migration — fail loudly."""
    params, p_fp32, _, _ = plans
    ocfg, _, opt = _planned_state(params, p_fp32)
    other = dict(params)
    other["w3"] = other.pop("w1")
    dst_layout = ss.layout_for_tree(
        plan_apply.planned_rules(p_fp32).spec_for, other
    )
    leaves = find_projected_state(opt).leaves
    with pytest.raises(ValueError, match="different param trees"):
        ss.migrate(leaves, dst_layout, quantize_for=lambda p: False)


# ---------------------------------------------------------------------------
# The control loop: kill → replan (8→4 shrink) → migrate → resume
# ---------------------------------------------------------------------------
_SMOKE_KW = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)
_STEPS = 12


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
    batch_fn = lambda step, host: data.batch(step, batch=4, seq=16, host=host)
    params = model.abstract_params()
    # Budget math: pick a per-device HBM so the 8-device pool fits the
    # fp32 plan while the 4-device pool forces the quantize knapsack —
    # the shrink really changes the layout, so migration really runs.
    h32 = solve_for_topology(params, 1, 10**12, quantize="off",
                             **_SMOKE_KW).predicted["hbm_total_bytes"]
    h8 = solve_for_topology(params, 1, 10**12, quantize="force",
                            **_SMOKE_KW).predicted["hbm_total_bytes"]
    per_dev = (h32 + h8) // 2 // 4
    assert 8 * per_dev >= h32 and h8 <= 4 * per_dev < h32
    return model, batch_fn, params, per_dev


def _ecfg(tmp, per_dev, shrink_at=None, **kw):
    topo = [Topology(8, per_dev)]
    if shrink_at is not None:
        topo.append(Topology(4, per_dev, from_step=shrink_at))
    base = dict(
        ckpt_dir=os.path.join(tmp, "ckpt"),
        total_steps=_STEPS,
        topology=tuple(topo),
        solve_kw=_SMOKE_KW,
        ckpt_every=2,
        log_every=100,
        backoff_base=0.0,
    )
    base.update(kw)
    return ElasticConfig(**base)


def _ocfg():
    return OptimizerConfig(name="coap-adamw", learning_rate=1e-3)


def test_topology_at():
    sched = (Topology(8, 100), Topology(4, 100, from_step=6))
    assert topology_at(sched, 0).n_devices == 8
    assert topology_at(sched, 5).n_devices == 8
    assert topology_at(sched, 6).n_devices == 4
    assert topology_at(sched, 99).n_devices == 4
    with pytest.raises(ValueError):
        topology_at((Topology(8, 100, from_step=5),), 2)


def test_kill_shrink_replan_resume_converges(smoke, tmp_path):
    """THE acceptance scenario: seeded kill at step 7 + topology shrink
    8→4 at step 6. The supervisor replans (new plan quantizes buckets),
    migrates the step-6 checkpoint and resumes to step 12 — final loss
    within tolerance of the uninterrupted 8-device baseline."""
    model, batch_fn, params, per_dev = smoke

    base = ElasticSupervisor(
        model, batch_fn, _ecfg(str(tmp_path / "base"), per_dev), ocfg=_ocfg()
    )
    state_base = base.run()
    assert base.events == [("resume", 0, None, 8)]

    inj = FaultInjector(FaultSchedule(kill_at=(7,)), seed=0)
    sup = ElasticSupervisor(
        model, batch_fn,
        _ecfg(str(tmp_path / "elastic"), per_dev, shrink_at=6),
        ocfg=_ocfg(), fault_injector=inj,
    )
    state = sup.run()

    assert int(state.step) == int(state_base.step) == _STEPS
    kinds = [e[0] for e in sup.events]
    assert kinds == ["resume", "crash", "migrate", "resume"]
    assert sup.events[-1][2] == 6  # resumed from the step-6 checkpoint
    assert sup.events[-1][3] == 4  # ...on the shrunk topology
    # The shrink genuinely changed the layout: the 4-device plan
    # quantizes buckets the 8-device plan kept fp32.
    plan8 = sup.plan_for(Topology(8, per_dev))
    plan4 = sup.plan_for(Topology(4, per_dev, from_step=6))
    assert sum(b.quantize for b in plan8.buckets) == 0
    assert sum(b.quantize for b in plan4.buckets) > 0
    # The migrated state is byte-exact against the target accounting.
    _assert_bytes_match_target(state.opt_state, plan4, model.init(
        jax.random.key(0)))

    batch = batch_fn(_STEPS + 1, 0)
    loss_base, _ = model.loss(state_base.params, batch)
    loss_elastic, _ = model.loss(state.params, batch)
    assert float(loss_elastic) == pytest.approx(float(loss_base),
                                                rel=0.15)


def test_two_resumes_same_checkpoint_identical_schedule(smoke, tmp_path):
    """Stagger phases and the resumed step count are a pure function of
    (checkpoint, topology): two independent supervisors resuming the
    same checkpoint derive bit-identical schedules and states."""
    model, batch_fn, params, per_dev = smoke
    tmp = str(tmp_path)

    # Produce a checkpoint at step 6 under the 8-device plan.
    seed_cfg = _ecfg(tmp, per_dev, total_steps=6)
    ElasticSupervisor(model, batch_fn, seed_cfg, ocfg=_ocfg()).run()
    assert 6 in ckpt.steps(seed_cfg.ckpt_dir)

    cfg = _ecfg(tmp, per_dev, shrink_at=6)
    outs = []
    for _ in range(2):
        sup = ElasticSupervisor(model, batch_fn, cfg, ocfg=_ocfg())
        topo = sup.current_topology()
        assert topo.n_devices == 4
        plan = sup.plan_for(topo)
        tx = sup._tx_for(plan)
        state, step, _ = sup.restore_into_plan(plan, tx)
        sig = stagger_signature(plan, params, _ocfg())
        outs.append((step, sig, state))
    (s1, sig1, st1), (s2, sig2, st2) = outs
    assert s1 == s2 == 6
    assert sig1 == sig2  # bit-identical stagger phases
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_falls_back_to_older(smoke, tmp_path):
    """A torn newest checkpoint (injected partial write at step 6) is
    skipped with an event; the supervisor resumes from step 4."""
    model, batch_fn, _, per_dev = smoke
    inj = FaultInjector(
        FaultSchedule(kill_at=(7,), torn_write_at=(6,)), seed=3
    )
    sup = ElasticSupervisor(
        model, batch_fn, _ecfg(str(tmp_path), per_dev),
        ocfg=_ocfg(), fault_injector=inj,
    )
    state = sup.run()
    assert int(state.step) == _STEPS
    kinds = [e[0] for e in sup.events]
    assert "torn_checkpoint" in kinds
    torn = next(e for e in sup.events if e[0] == "torn_checkpoint")
    assert torn[1] == 6
    resumed = [e for e in sup.events if e[0] == "resume"]
    assert resumed[-1][2] == 4  # fell back past the torn step-6 ckpt


def test_crash_budget_exhaustion_propagates(smoke, tmp_path):
    """More injected kills than the crash budget allows: the supervisor
    stops retrying and the last InjectedKill propagates."""
    model, batch_fn, _, per_dev = smoke
    inj = FaultInjector(FaultSchedule(kill_at=(1, 2, 3)), seed=0)
    sup = ElasticSupervisor(
        model, batch_fn,
        _ecfg(str(tmp_path), per_dev, max_crashes=1),
        ocfg=_ocfg(), fault_injector=inj,
    )
    with pytest.raises(InjectedKill):
        sup.run()
    assert inj.kills >= 2


# ---------------------------------------------------------------------------
# Restart-policy primitives (fault_tolerance satellites)
# ---------------------------------------------------------------------------
def test_straggler_detector_seeds_cleanly():
    """Regression: the FIRST observation seeds mean exactly (no EWMA
    against the zero-initialized mean), so an honest constant step time
    never reads as an outlier during or right after warmup."""
    det = StragglerDetector(z_threshold=3.0, warmup=5)
    assert not det.observe(0.25)
    assert det.mean == pytest.approx(0.25)
    assert det.var == 0.0
    for _ in range(10):
        assert not det.observe(0.25)
    assert det.flagged == 0
    assert det.observe(1.25)  # genuine outlier still flags
    assert det.flagged == 1


def test_heartbeat_missing_vs_stale(tmp_path, monkeypatch):
    hb = Heartbeat(str(tmp_path / "hb.json"), timeout=10.0)
    assert hb.status() == "missing"
    assert not hb.is_alive()
    hb.beat(3)
    assert hb.status() == "alive" and hb.is_alive()
    assert hb.last_step() == 3
    import repro.train.fault_tolerance as ft
    real = ft.time.time()
    monkeypatch.setattr(ft.time, "time", lambda: real + 11.0)
    assert hb.status() == "stale"
    assert not hb.is_alive()
    os.remove(hb.path)
    assert hb.status() == "missing"


def test_heartbeat_creates_parent_dir(tmp_path):
    hb = Heartbeat(str(tmp_path / "fresh" / "hb.json"))
    hb.beat(0)
    assert hb.is_alive()


def test_crash_budget_sliding_window():
    now = [1000.0]
    cb = CrashBudget(max_crashes=2, window_seconds=60.0,
                     time_fn=lambda: now[0])
    cb.record(); cb.record()
    assert not cb.exhausted()
    cb.record()  # 3rd crash inside the window
    assert cb.exhausted()
    now[0] += 61.0  # the window slides: old crashes expire
    assert not cb.exhausted()
    cb.record()
    assert not cb.exhausted()


def test_backoff_delay_shape():
    import random as pyrandom
    rng = pyrandom.Random(0)
    assert backoff_delay(1, 0.0, 30.0, 0.1, rng) == 0.0
    d1 = backoff_delay(1, 1.0, 30.0, 0.0, rng)
    d2 = backoff_delay(2, 1.0, 30.0, 0.0, rng)
    d5 = backoff_delay(5, 1.0, 4.0, 0.0, rng)
    assert d1 == 1.0 and d2 == 2.0 and d5 == 4.0  # doubling, capped
    dj = backoff_delay(3, 1.0, 30.0, 0.5, pyrandom.Random(0))
    assert 4.0 <= dj <= 6.0  # jitter only ever lengthens


def test_run_with_restart_backoff_and_budget():
    sleeps = []
    attempts = []

    def attempt(i):
        attempts.append(i)
        if i < 2:
            raise RuntimeError(f"boom {i}")
        return "ok"

    out = run_with_restart(
        attempt,
        crash_budget=CrashBudget(max_crashes=5, window_seconds=1e9),
        backoff_base=1.0, backoff_cap=30.0, backoff_jitter=0.0,
        sleep_fn=sleeps.append, seed=0,
    )
    assert out == "ok"
    assert attempts == [0, 1, 2]
    assert sleeps == [1.0, 2.0]  # exponential between attempts

    def always_fail(i):
        raise RuntimeError("down")

    with pytest.raises(RuntimeError, match="down"):
        run_with_restart(
            always_fail,
            crash_budget=CrashBudget(max_crashes=2, window_seconds=1e9),
            backoff_base=0.0, sleep_fn=sleeps.append,
        )


def test_fault_schedule_generate_is_deterministic():
    a = FaultSchedule.generate(seed=5, total_steps=100, n_kills=2,
                               n_torn=1, n_slow=3)
    b = FaultSchedule.generate(seed=5, total_steps=100, n_kills=2,
                               n_torn=1, n_slow=3)
    c = FaultSchedule.generate(seed=6, total_steps=100, n_kills=2,
                               n_torn=1, n_slow=3)
    assert a == b
    assert a != c
    assert all(1 <= s < 100 for s in a.kill_at + a.torn_write_at)


def test_injected_faults_fire_once():
    inj = FaultInjector(FaultSchedule(kill_at=(4,),
                                      heartbeat_silence=((2, 5),),
                                      slow_steps=((3, 0.7),)))
    with pytest.raises(InjectedKill):
        inj.maybe_kill(4)
    inj.maybe_kill(4)  # one-shot: a resumed run passes step 4 unharmed
    assert inj.heartbeat_silent(2) and inj.heartbeat_silent(4)
    assert not inj.heartbeat_silent(5)
    assert inj.slow_delay(3) == 0.7
    assert inj.slow_delay(4) == 0.0


# ---------------------------------------------------------------------------
# Transpose-flip migration (the exact* QR transform)
# ---------------------------------------------------------------------------
def _flip_transpose(plan):
    """The same plan with ``spec.transpose`` flipped on every projected
    (non-conv, non-dense) bucket — a pure orientation change."""
    buckets = [
        dataclasses.replace(
            b, spec=b.spec._replace(transpose=not b.spec.transpose)
        ) if b.kind == "project" else b
        for b in plan.buckets
    ]
    return dataclasses.replace(plan, buckets=buckets)


def test_migrate_transpose_flip_is_exact(plans):
    """Orientation flip (spec.transpose toggled, same kind): migration
    TRANSFORMS the state instead of resetting it. The de-projected first
    moment is preserved exactly (up to fp32 QR roundoff), the new P is
    exactly orthonormal, v stays nonnegative, count is preserved, and the
    landed bytes match the flipped target's accounting."""
    from repro.core import projector as proj

    params, p_fp32, _, _ = plans
    p_flip = _flip_transpose(p_fp32)
    assert any(
        a.spec.transpose != b.spec.transpose
        for a, b in zip(p_fp32.buckets, p_flip.buckets)
    )
    ocfg, _, opt = _planned_state(params, p_fp32)
    migrated = migrate_opt_state(opt, p_fp32, p_flip, params, ocfg)

    src = _by_path(find_projected_state(opt).leaves)
    dst = _by_path(find_projected_state(migrated).leaves)
    assert set(src) == set(dst)
    checked = 0
    for path, (d, dspec) in dst.items():
        if not hasattr(d, "p"):
            continue  # conv/dense: spec unchanged, covered elsewhere
        s, sspec = src[path]
        assert dspec.transpose != sspec.transpose
        # De-projected first moment, in the weight's own orientation:
        # from_canonical(m @ P^T). Must be reproduced exactly.
        full = lambda leaf, spec: np.asarray(proj.from_canonical(
            proj.backproject(jnp.asarray(leaf.m, jnp.float32),
                             jnp.asarray(leaf.p, jnp.float32)),
            spec,
        ))
        np.testing.assert_allclose(full(d, dspec), full(s, sspec),
                                   rtol=1e-5, atol=1e-6)
        # The flipped P is exactly orthonormal (it is a QR Q factor).
        p_new = np.asarray(d.p, np.float64)
        gram = np.einsum("...mr,...mk->...rk", p_new, p_new)
        eye = np.broadcast_to(np.eye(gram.shape[-1]), gram.shape)
        np.testing.assert_allclose(gram, eye, atol=1e-5)
        # Variance transports nonnegatively (diagonal map of squares).
        assert np.all(np.asarray(d.v) >= 0)
        assert np.all(np.isfinite(np.asarray(d.v)))
        checked += 1
    assert checked >= 1
    assert int(find_projected_state(migrated).count) == int(
        find_projected_state(opt).count
    )
    _assert_bytes_match_target(migrated, p_flip, params)


def test_migrate_transpose_flip_zero_moments(plans):
    """Edge case: a fresh (zero-moment) state flips without NaNs — QR of
    zeros yields a valid orthonormal P and exactly-zero moments."""
    params, p_fp32, _, _ = plans
    p_flip = _flip_transpose(p_fp32)
    ocfg, _, opt = _planned_state(params, p_fp32, steps=0)
    migrated = migrate_opt_state(opt, p_fp32, p_flip, params, ocfg)
    for d, _ in _by_path(find_projected_state(migrated).leaves).values():
        if not hasattr(d, "p"):
            continue
        assert np.all(np.isfinite(np.asarray(d.p)))
        np.testing.assert_array_equal(np.asarray(d.m),
                                      np.zeros_like(np.asarray(d.m)))
        np.testing.assert_array_equal(np.asarray(d.v),
                                      np.zeros_like(np.asarray(d.v)))
    _assert_bytes_match_target(migrated, p_flip, params)


# ---------------------------------------------------------------------------
# Bad plan meta in a checkpoint (regression: crash -> graceful fallback)
# ---------------------------------------------------------------------------
def test_restore_skips_undecodable_plan_meta(smoke, tmp_path):
    """A checkpoint whose manifest carries an undecodable or unknown-
    version plan artifact must be SKIPPED like a torn checkpoint (with a
    ``bad_plan_meta`` event), not crash the supervisor."""
    import json as _json

    model, batch_fn, _, per_dev = smoke
    cfg = _ecfg(str(tmp_path), per_dev, total_steps=6)
    ElasticSupervisor(model, batch_fn, cfg, ocfg=_ocfg()).run()
    steps = ckpt.steps(cfg.ckpt_dir)
    assert steps[-2:] == [4, 6]

    def garble(step, mutate):
        mpath = os.path.join(cfg.ckpt_dir, f"ckpt_{step:08d}",
                             "manifest.json")
        with open(mpath) as f:
            man = _json.load(f)
        mutate(man["meta"]["plan"])
        with open(mpath, "w") as f:
            _json.dump(man, f)

    # Newest: unknown future plan codec. Next: structurally garbage.
    garble(6, lambda p: p.__setitem__("codec", "coap-plan/v99"))
    garble(4, lambda p: (p.clear(), p.__setitem__("junk", 1)))

    sup = ElasticSupervisor(model, batch_fn, cfg, ocfg=_ocfg())
    plan = sup.plan_for(sup.current_topology())
    state, step, _ = sup.restore_into_plan(plan, sup._tx_for(plan))
    assert step == 2  # fell back past BOTH bad-meta checkpoints
    assert int(state.step) == 2
    bad = [e for e in sup.events if e[0] == "bad_plan_meta"]
    assert [e[1] for e in bad] == [6, 4]


# ---------------------------------------------------------------------------
# Preemption-notice drain: zero lost steps (vs reactive <= ckpt_every)
# ---------------------------------------------------------------------------
def test_drain_zero_lost_steps_vs_reactive_rollback(smoke, tmp_path):
    """An injected NOTICE at step 9 drains: checkpoint lands at exactly
    step 9 and the relaunch resumes there — zero lost steps, no crash
    charged. A no-warning KILL at the same step rolls back to the last
    periodic checkpoint, losing up to ckpt_every steps."""
    model, batch_fn, _, per_dev = smoke

    inj = FaultInjector(FaultSchedule(notice_at=((9, 30.0),)), seed=0)
    sup = ElasticSupervisor(
        model, batch_fn, _ecfg(str(tmp_path / "drain"), per_dev),
        ocfg=_ocfg(), fault_injector=inj,
    )
    state = sup.run()
    assert int(state.step) == _STEPS
    assert inj.notices == 1
    kinds = [e[0] for e in sup.events]
    assert "crash" not in kinds  # a drain never charges the crash budget
    drain = next(e for e in sup.events if e[0] == "drain")
    assert drain[2] == 9
    resumes = [e for e in sup.events if e[0] == "resume"]
    assert resumes[-1][2] == 9  # zero lost steps

    inj2 = FaultInjector(FaultSchedule(kill_at=(9,)), seed=0)
    sup2 = ElasticSupervisor(
        model, batch_fn, _ecfg(str(tmp_path / "kill"), per_dev),
        ocfg=_ocfg(), fault_injector=inj2,
    )
    state2 = sup2.run()
    assert int(state2.step) == _STEPS
    resumes2 = [e for e in sup2.events if e[0] == "resume"]
    lost = 9 - resumes2[-1][2]
    assert 0 < lost <= 2  # rolled back, bounded by ckpt_every


# ---------------------------------------------------------------------------
# Resume-latency-aware replanning (solver knob + supervisor plumbing)
# ---------------------------------------------------------------------------
def test_solver_resume_aware_flips_already_int8_buckets_first():
    """Two projected buckets; the budget forces ONE quantize flip.
    History-free, the knapsack flips the bucket with the biggest byte
    saving. Resume-aware with a short horizon, the bucket that was
    ALREADY int8 under the previous plan flips instead (its churn is
    free); a long horizon amortizes the penalty away. With the knobs off
    the output is bit-identical to the history-free solve."""
    key = jax.random.key(3)
    params = {
        "big": 0.3 * jax.random.normal(jax.random.fold_in(key, 0), (64, 32)),
        "small": 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (48, 24)),
    }
    kw = dict(min_dim=8, t_update=4, lam=2, stagger_groups=2)
    p_off = solve(params, None, quantize="off", **kw)
    assert len(p_off.buckets) == 2
    budget = p_off.predicted["hbm_total_bytes"] - 1  # forces one flip

    def quantized_paths(plan):
        return sorted(p for b in plan.buckets if b.quantize for p in b.paths)

    base = solve(params, budget, **kw)
    assert quantized_paths(base) == ["big"]  # biggest saving wins

    # Previous plan: "small" was int8.
    prev = dataclasses.replace(
        p_off,
        buckets=[
            dataclasses.replace(b, quantize=("small" in b.paths))
            for b in p_off.buckets
        ],
    )
    aware = solve(params, budget, prev_plan=prev, resume_horizon_steps=1,
                  **kw)
    assert quantized_paths(aware) == ["small"]  # free flip preferred
    assert "resume_aware" in aware.cost
    assert aware.cost["resume_aware"]["resume_horizon_steps"] == 1

    # A horizon long enough that the per-step churn charge falls below
    # one roofline byte: the penalty is fully amortized and the solver
    # re-layouts freely (the knapsack reverts to biggest-saving-first).
    from repro.launch.roofline import HBM_BW
    from repro.plan import cost as pcost

    pen_s = pcost.Calibration.load().resume_penalty_s_per_bucket()
    h_long = max(1, int(pen_s * HBM_BW))
    long = solve(params, budget, prev_plan=prev,
                 resume_horizon_steps=h_long, **kw)
    assert quantized_paths(long) == ["big"]  # penalty amortized away

    off = solve(params, budget, prev_plan=prev, resume_horizon_steps=0,
                **kw)
    assert off.to_dict() == base.to_dict()  # knobs off: bit-identical


def test_supervisor_plans_resume_aware_against_checkpoint_plan(
    smoke, tmp_path
):
    """With ``resume_horizon_steps`` set, the supervisor feeds the newest
    checkpoint's plan into the solve (visible as the plan's
    ``resume_aware`` cost block); with no checkpoints yet, the solve is
    history-free."""
    model, batch_fn, _, per_dev = smoke
    cfg = _ecfg(str(tmp_path), per_dev, total_steps=6,
                resume_horizon_steps=500)
    sup = ElasticSupervisor(model, batch_fn, cfg, ocfg=_ocfg())
    first = sup.plan_for(Topology(8, per_dev))
    assert "resume_aware" not in first.cost  # nothing to resume from yet
    sup.run()

    sup2 = ElasticSupervisor(model, batch_fn, cfg, ocfg=_ocfg())
    replanned = sup2.plan_for(Topology(4, per_dev, from_step=6))
    assert "resume_aware" in replanned.cost
    ra = replanned.cost["resume_aware"]
    assert ra["resume_horizon_steps"] == 500
    assert ra["penalty_s_per_step_per_bucket"] > 0


# ---------------------------------------------------------------------------
# Fleet consensus through the supervisor (two hosts, one artifact)
# ---------------------------------------------------------------------------
def test_two_supervisors_agree_on_one_plan_artifact(smoke, tmp_path):
    """Two supervisors sharing a fleet_dir plan the same replan epoch:
    exactly one publishes, the other adopts, and both train under the
    IDENTICAL coap-plan/v1 dict."""
    model, batch_fn, _, per_dev = smoke
    fleet_dir = str(tmp_path / "fleet")
    sups = [
        ElasticSupervisor(
            model, batch_fn,
            _ecfg(str(tmp_path / host), per_dev, fleet_dir=fleet_dir,
                  host_id=host),
            ocfg=_ocfg(),
        )
        for host in ("host-a", "host-b")
    ]
    topo = Topology(4, per_dev, from_step=6)
    plan_a = sups[0].plan_for(topo)
    plan_b = sups[1].plan_for(topo)
    assert plan_a.to_dict() == plan_b.to_dict()
    roles = [e[0] for s in sups for e in s.events
             if e[0].startswith("plan_")]
    assert sorted(roles) == ["plan_adopted", "plan_published"]
