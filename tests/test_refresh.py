"""Staggered projection-refresh schedule + fused refresh path integration.

Covers the refresh-overhaul contracts:
  * cadence parity — under stagger every leaf still refreshes exactly every
    ``T_u`` steps and recalibrates every ``λ·T_u`` steps, just phase-shifted;
  * Eqn-7 initialization at t=0 runs for every leaf regardless of phase;
  * phases are deterministic (pure function of the tree) and identical
    between bucketed and per-leaf execution;
  * ``stagger=False`` restores the synchronized schedule;
  * bf16 gradients stream through the fused paths without an fp32 G
    materialization changing numerics;
  * benchmark gates: staggered worst-step refresh cost ≥4× below
    synchronized on the LLaMA-1B bucket structure, and the fused Eqn-6
    kernel streams ≥2× fewer G bytes than the unfused einsum chain.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coap_adam import (
    ProjLeaf,
    ProjectedAdamConfig,
    _phase_groups,
    scale_by_projected_adam,
    stagger_phases,
)
from repro.core.projector import ProjectionRules

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)


def _cfg(**kw):
    kw.setdefault("rules", ProjectionRules(rank=16, min_dim=8))
    return ProjectedAdamConfig(**kw)


def _multibucket_params():
    """Three congruence buckets: 4x(96,64) + 2x(128,48) + 1x(80,72)."""
    p = {f"a{i}": {"w": jnp.zeros((96, 64))} for i in range(4)}
    p.update({f"b{i}": {"w": jnp.zeros((128, 48))} for i in range(2)})
    p["c0"] = {"w": jnp.zeros((80, 72))}
    return p


def _grads(params, seed=0):
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [
            jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )


def _proj_ps(state):
    """Ordered list of every projected leaf's P."""
    return [
        x.p
        for x in jax.tree_util.tree_leaves(
            state.leaves, is_leaf=lambda x: isinstance(x, ProjLeaf)
        )
        if isinstance(x, ProjLeaf)
    ]


def _change_steps(tx, params, n_steps, seed=1):
    """Runs n_steps and returns, per projected leaf, the set of counts at
    which its P changed."""
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, None))
    prev = _proj_ps(state)
    changed = [set() for _ in prev]
    for count in range(n_steps):
        _, state = step(_grads(params, seed=seed + count), state)
        now = _proj_ps(state)
        for i, (a, b) in enumerate(zip(prev, now)):
            if bool(jnp.max(jnp.abs(a - b)) > 1e-7):
                changed[i].add(count)
        prev = now
    return changed, state


# ---------------------------------------------------------------------------
# phase allocator properties
# ---------------------------------------------------------------------------
def test_stagger_phases_deterministic_and_bounded():
    sizes = [96, 48, 24]
    a = stagger_phases(sizes, 40, 8)
    b = stagger_phases(sizes, 40, 8)
    assert a == b  # pure function of the tree — identical across restarts
    for phases, size in zip(a, sizes):
        assert len(phases) == size
        assert all(0 <= ph < 40 for ph in phases)
        assert list(phases) == sorted(phases)  # monotone -> contiguous runs
        assert len(_phase_groups(phases)) <= 8
    # buckets don't all share one phase (the schedule actually staggers)
    assert len({ph for phases in a for ph in phases}) > 1


def test_stagger_phases_degenerate_cases():
    # T_u=1 (flora): everything phase 0 — schedule unchanged
    assert stagger_phases([5, 3], 1, 8) == [(0,) * 5, (0,) * 3]
    # single singleton bucket: phase 0 (matches the unstaggered schedule)
    assert stagger_phases([1], 200, 8) == [(0,)]


# ---------------------------------------------------------------------------
# schedule cadence
# ---------------------------------------------------------------------------
def test_staggered_cadence_every_leaf_period_t_u():
    """Every leaf refreshes at count 0 (Eqn-7 init) and then exactly when
    (count + phase) % T_u == 0 — period T_u, phase per bucket group."""
    t_u = 4
    params = _multibucket_params()
    tx = scale_by_projected_adam(_cfg(t_update=t_u, lam=2, stagger=True))
    n = 2 * 2 * t_u + 1
    changed, _ = _change_steps(tx, params, n)
    phase_lists = stagger_phases([4, 2, 1], t_u, 8)
    flat_phases = [ph for phases in phase_lists for ph in phases]
    assert len(changed) == len(flat_phases)
    for leaf_changed, ph in zip(changed, flat_phases):
        want = {
            c for c in range(n) if c == 0 or (c + ph) % t_u == 0
        }
        assert leaf_changed == want, (ph, leaf_changed, want)
    # staggering engaged: not all leaves share one refresh schedule
    assert len({frozenset(c) for c in changed}) > 1


def test_staggered_recalibration_cadence():
    """With eqn6_lr=0 the Eqn-6 refresh is a no-op, so P changes ONLY at
    Eqn-7 recalibration steps: count 0 and (count + phase) % (λ·T_u) == 0."""
    t_u, lam = 3, 2
    params = _multibucket_params()
    tx = scale_by_projected_adam(
        _cfg(t_update=t_u, lam=lam, stagger=True, eqn6_lr=0.0)
    )
    n = 2 * lam * t_u + 1
    changed, _ = _change_steps(tx, params, n)
    phase_lists = stagger_phases([4, 2, 1], t_u, 8)
    flat_phases = [ph for phases in phase_lists for ph in phases]
    for leaf_changed, ph in zip(changed, flat_phases):
        want = {
            c for c in range(n) if c == 0 or (c + ph) % (lam * t_u) == 0
        }
        assert leaf_changed == want, (ph, leaf_changed, want)


def test_eqn7_init_at_t0_for_all_phases():
    """At count 0 every projected leaf must get the Eqn-7 initialization:
    P's columns come out of the low-cost SVD orthonormal, nonzero-phase
    leaves included."""
    params = _multibucket_params()
    tx = scale_by_projected_adam(_cfg(t_update=4, lam=2, stagger=True))
    state = tx.init(params)
    _, state = jax.jit(lambda g, s: tx.update(g, s, None))(
        _grads(params), state
    )
    for p in _proj_ps(state):
        ptp = np.asarray(jnp.einsum("nr,nk->rk", p, p))
        np.testing.assert_allclose(ptp, np.eye(p.shape[-1]), atol=1e-4)


def test_stagger_false_is_synchronized():
    """stagger=False: every projected leaf refreshes at the same steps
    (count % T_u == 0), reproducing the paper-faithful schedule."""
    t_u = 3
    params = _multibucket_params()
    tx = scale_by_projected_adam(_cfg(t_update=t_u, lam=2, stagger=False))
    n = 2 * t_u + 1
    changed, _ = _change_steps(tx, params, n)
    want = {c for c in range(n) if c % t_u == 0}
    for leaf_changed in changed:
        assert leaf_changed == want, (leaf_changed, want)


def test_schedule_deterministic_across_rebuilds():
    """Two independently-built optimizers must produce bit-identical
    trajectories (phases are structural, not runtime-random)."""
    params = _multibucket_params()
    outs = []
    for _ in range(2):
        tx = scale_by_projected_adam(_cfg(t_update=3, lam=2, stagger=True))
        state = tx.init(params)
        step = jax.jit(lambda g, s: tx.update(g, s, None))
        for i in range(5):
            _, state = step(_grads(params, seed=10 + i), state)
        outs.append(state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bucketed vs per-leaf parity under stagger
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("strategy", ["coap", "galore", "flora"])
def test_staggered_bucketed_matches_per_leaf(quantize, strategy):
    """Per-leaf groups inherit the exact phase their leaf has inside its
    bucket, so bucketed and per-leaf execution refresh at the same steps and
    agree bit-for-bit on int8 states (float to stacking ulp noise)."""
    params = _multibucket_params()
    g = _grads(params, seed=3)
    outs = {}
    for bucketed in (True, False):
        tx = scale_by_projected_adam(
            _cfg(strategy=strategy, quantize=quantize, t_update=3,
                 stagger=True, bucket_leaves=bucketed)
        )
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(4):
            upd, state = step(g, state)
        outs[bucketed] = (upd, state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# bf16 gradient streaming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
def test_bf16_gradients_stream_without_numeric_drift(quantize):
    """bf16 grads feed the fused kernels directly (per-tile upcast). The
    optimizer state after a step must be BIT-IDENTICAL to feeding the same
    values pre-cast to fp32 (upcasting bf16 is exact), and the returned
    update must be the fp32 result rounded once to bf16."""
    params = _multibucket_params()
    g32 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32),
        jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16), _grads(params, seed=5)
        ),
    )
    g16 = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), g32)
    out = {}
    for name, g in [("fp32", g32), ("bf16", g16)]:
        tx = scale_by_projected_adam(
            _cfg(t_update=2, lam=2, quantize=quantize)
        )
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(3):
            upd, state = step(g, state)
        out[name] = (upd, state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(out["fp32"][1]),
                    jax.tree_util.tree_leaves(out["bf16"][1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(out["fp32"][0]),
                    jax.tree_util.tree_leaves(out["bf16"][0])):
        # update dtype follows the gradient dtype: the bf16 run's update is
        # the fp32 run's update rounded once to bf16
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(a).astype(jnp.bfloat16)
                       .astype(jnp.float32)),
            np.asarray(jnp.asarray(b).astype(jnp.float32)),
        )


# ---------------------------------------------------------------------------
# benchmark gates (acceptance criteria)
# ---------------------------------------------------------------------------
def test_stagger_worst_step_gate():
    """Staggered schedule must cut the worst-step refresh cost >=4x vs the
    synchronized schedule on the multi-bucket LLaMA-1B tree (bytes-based
    accounting; the benchmark also reports measured wall time)."""
    from benchmarks.overhead import refresh_stagger_report

    rep = refresh_stagger_report(measure=False)
    assert rep["worst_step_bytes_ratio"] >= 4.0, rep["worst_step_bytes_ratio"]
    # stagger redistributes, never adds, refresh work
    assert (rep["synchronized"]["total_bytes_per_period"]
            == rep["staggered"]["total_bytes_per_period"])


def test_eqn6_fused_bytes_gate():
    """Fused Eqn-6 must stream >=2x fewer G bytes than the unfused einsum
    chain (and >=2x fewer total bytes under the BENCH_overhead-style
    per-dispatch cost_analysis accounting)."""
    from benchmarks.overhead import LLAMA1B_MATS, eqn6_fused_vs_unfused

    rows = eqn6_fused_vs_unfused(LLAMA1B_MATS[:1], rank=512)
    for label, row in rows.items():
        assert row["g_stream_ratio"] >= 2.0, (label, row["g_stream_ratio"])
        assert row["ratio"] >= 2.0, (label, row["ratio"])
        assert row["ratio_conservative"] >= 2.0, (
            label, row["ratio_conservative"]
        )
        assert row["launches_fused"] == 1
